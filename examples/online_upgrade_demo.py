"""The paper's headline demo, live: hot-swap FILE PROVENANCE onto a
running file system (§6) with a measured service interruption, strip it
again, and hot-swap a trainer module mid-run (§4.8) — the same
quiesce -> extract -> migrate -> restore protocol every time.

    PYTHONPATH=src python examples/online_upgrade_demo.py

Exits nonzero if any claim fails (CI runs this), printing the failed
check instead of a bare traceback.
"""

import sys
import threading
import time

from repro.configs import registry
from repro.core.upgrade import transfer_state, unwrap_layer, wrap_layer
from repro.fs.mounts import make_mount
from repro.fs.prov import ProvFilesystem
from repro.train.trainer import Trainer


def prov_hot_swap_under_load():
    print("== 1. hot-swap file provenance onto a live mount (paper §6) ==")
    mf = make_mount("bento", n_blocks=16384)
    v = mf.view
    v.makedirs("/w")
    stop = threading.Event()
    ops = {"n": 0, "errors": 0}

    def workload():
        i = 0
        while not stop.is_set():
            try:
                v.write_file(f"/w/f{i % 32}", b"payload" * 512)
                v.read_file(f"/w/f{i % 32}")
                ops["n"] += 2
            except Exception:  # noqa: BLE001
                ops["errors"] += 1
            i += 1

    t = threading.Thread(target=workload, daemon=True)
    t.start()
    time.sleep(0.4)

    wrap = wrap_layer(mf.mount, ProvFilesystem)      # plain -> prov, live
    print(f"  provenance ON : pause {wrap['total_s']*1e3:6.2f} ms "
          f"(quiesce {wrap['quiesce_s']*1e3:.2f} ms) — paper's demo: ~15 ms")
    time.sleep(0.4)
    recs = v.read_provenance()
    sample = [(r["op"], r["name"] or r["ino"]) for r in recs[:3]]
    print(f"  {len(recs)} provenance records so far, e.g. {sample}")

    unwrap = unwrap_layer(mf.mount)                  # prov -> plain, live
    print(f"  provenance OFF: pause {unwrap['total_s']*1e3:6.2f} ms "
          f"(log stays durable for the next wrap)")
    time.sleep(0.2)
    stop.set()
    t.join(5)
    print(f"  {ops['n']} ops during swaps, {ops['errors']} failures")
    assert ops["errors"] == 0, "a workload op failed during a swap"
    assert ops["n"] > 0, "the workload never ran"
    assert recs, "no provenance records were captured under load"
    assert all(r["op"] in ("create", "write") for r in recs), \
        "unexpected record op in the workload window"
    mf.close()


def trainer_module_upgrade():
    print("== 2. trainer hot-swap (optimizer hyper-upgrade mid-run) ==")
    b = registry.get("smollm-135m")
    run_v1 = b.run.replace(microbatch_per_data_shard=0, learning_rate=3e-4)
    t1 = Trainer(b.smoke, run_v1, global_batch=4, seq_len=32)
    t1.train(5)
    print(f"  v1 @ step {t1.step_idx}: loss {t1.metrics_log[-1]['loss']:.4f}")

    # "new release": higher LR schedule — new Trainer, transferred state
    run_v2 = run_v1.replace(learning_rate=1e-3)
    t2 = Trainer(b.smoke, run_v2, global_batch=4, seq_len=32)
    t2.VERSION = 2
    transfer_state(t1, t2)  # quiesce/extract/restore — moments preserved
    assert t2.step_idx == 5
    t2.train(10)
    print(f"  v2 @ step {t2.step_idx}: loss {t2.metrics_log[-1]['loss']:.4f} "
          "(optimizer moments survived the swap)")


if __name__ == "__main__":
    try:
        prov_hot_swap_under_load()
        trainer_module_upgrade()
    except AssertionError as e:
        print(f"DEMO FAILED: {e}", file=sys.stderr)
        sys.exit(1)
    print("OK")
