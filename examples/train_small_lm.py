"""End-to-end training driver: train a ~100M-class model (SmolLM-135M smoke
or full config) for a few hundred steps with FS-backed data shards, failure
injection, checkpoint/restart, and deterministic resume.

    PYTHONPATH=src python examples/train_small_lm.py --steps 200
    PYTHONPATH=src python examples/train_small_lm.py --steps 50 --full  # real 135M
"""

import argparse
import time

from repro.configs import registry
from repro.data.pipeline import FsShardReader, SyntheticLM, write_shards
from repro.fs.mounts import make_mount
from repro.train.trainer import Trainer, WorkerFailure


class FsDataset:
    """Adapter: serve training batches from Bento-FS shards."""

    def __init__(self, view, cfg, global_batch, seq_len, n_shards=8):
        base = SyntheticLM(cfg, global_batch, seq_len, seed=1234)
        write_shards(view, base, n_shards=n_shards)
        self.reader = FsShardReader(view)

    def batch(self, step: int):
        return self.reader.read(step)


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=200)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--seq", type=int, default=128)
    ap.add_argument("--full", action="store_true",
                    help="train the real 135M config (slow on CPU)")
    ap.add_argument("--fail-at", type=int, default=-1,
                    help="inject a node failure at this step")
    args = ap.parse_args()

    bundle = registry.get("smollm-135m")
    cfg = bundle.model if args.full else bundle.smoke
    run = bundle.run.replace(microbatch_per_data_shard=0, learning_rate=6e-4)

    mf = make_mount("bento", n_blocks=65536)
    data = FsDataset(mf.view, cfg, args.batch, args.seq)

    armed = {"on": args.fail_at >= 0}

    def failure_hook(step):
        if armed["on"] and step == args.fail_at:
            armed["on"] = False
            raise WorkerFailure(f"injected node loss at step {step}")

    t = Trainer(cfg, run, global_batch=args.batch, seq_len=args.seq,
                ckpt_view=mf.view, ckpt_every=max(args.steps // 10, 1),
                failure_hook=failure_hook if args.fail_at >= 0 else None,
                data=data)
    t0 = time.time()
    t.train(args.steps)
    wall = time.time() - t0
    ls = [m["loss"] for m in t.metrics_log]
    toks = args.steps * args.batch * args.seq
    print(f"{cfg.name}: {args.steps} steps in {wall:.1f}s "
          f"({toks/wall:,.0f} tok/s 1xCPU) loss {ls[0]:.3f} -> {ls[-1]:.3f} "
          f"recoveries={t.recoveries} shard_retries={data.reader.retries}")
    assert ls[-1] < ls[0], "training must reduce loss"
    mf.close()


if __name__ == "__main__":
    main()
