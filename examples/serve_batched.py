"""Batched serving example: prefill a batch of prompts, then run a greedy
continuous decode loop with per-step latency stats — across model families
(dense / SSM / hybrid take different cache paths through the same API).

    PYTHONPATH=src python examples/serve_batched.py --arch rwkv6-7b
    PYTHONPATH=src python examples/serve_batched.py --arch smollm-135m --gen 32
"""

import argparse
import time

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs import registry
from repro.distributed.sharding import ShardingCtx
from repro.models import lm, params as P
from repro.serve.step import make_decode_step, make_prefill_step


def main():
    ap = argparse.ArgumentParser()
    ap.add_argument("--arch", default="smollm-135m")
    ap.add_argument("--batch", type=int, default=4)
    ap.add_argument("--prompt-len", type=int, default=32)
    ap.add_argument("--gen", type=int, default=16)
    args = ap.parse_args()

    bundle = registry.get(args.arch)
    cfg, run = bundle.smoke, bundle.run
    ctx = ShardingCtx.null()
    rng = jax.random.PRNGKey(0)
    params = P.materialize(lm.param_specs(cfg), rng, dtype=run.compute_dtype)

    batch = {"tokens": jax.random.randint(
        rng, (args.batch, args.prompt_len), 0, cfg.vocab_size, jnp.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.02 * jnp.ones(
            (args.batch, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frame_embeds"] = 0.02 * jnp.ones(
            (args.batch, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)

    prefill = jax.jit(make_prefill_step(cfg, run, ctx))
    decode = jax.jit(make_decode_step(cfg, run, ctx))

    t0 = time.time()
    tok, cache = prefill(params, batch)
    jax.block_until_ready(tok)
    t_prefill = time.time() - t0

    # transformer-family caches need room for generated tokens (ssm/hybrid
    # states are fixed-size; SWA ring buffers stay window-sized)
    if cfg.num_heads > 0 and cfg.sliding_window == 0 and cfg.family != "ssm":
        def pad(x):
            if x.ndim == 5 and x.shape[2] == args.prompt_len:
                return jnp.pad(x, [(0, 0), (0, 0), (0, args.gen), (0, 0), (0, 0)])
            return x
        cache = jax.tree.map(pad, cache)

    lat = []
    outs = [np.asarray(tok)]
    for i in range(args.gen - 1):
        t1 = time.time()
        tok, cache = decode(params, cache,
                            {"tokens": tok[:, None],
                             "pos": jnp.int32(args.prompt_len + i)})
        jax.block_until_ready(tok)
        lat.append(time.time() - t1)
    outs = np.stack(outs, 0)

    lat_ms = np.array(lat[1:]) * 1e3  # skip first (compile already done, warmup)
    print(f"{cfg.name}: batch={args.batch} prefill={t_prefill*1e3:.0f}ms "
          f"decode p50={np.percentile(lat_ms,50):.1f}ms "
          f"p99={np.percentile(lat_ms,99):.1f}ms/token "
          f"throughput={args.batch/np.mean(lat_ms)*1e3:,.0f} tok/s")


if __name__ == "__main__":
    main()
