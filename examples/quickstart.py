"""Quickstart: the whole system in ~60 lines.

Mount a journaled Bento file system, train a small LM whose checkpoints
flow through it, hot-upgrade the file system mid-run (paper §4.8), and
serve a few greedy tokens from the trained weights.

    PYTHONPATH=src python examples/quickstart.py
"""

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core.upgrade import upgrade
from repro.distributed.sharding import ShardingCtx
from repro.fs.ext4like import Ext4LikeFileSystem
from repro.fs.mounts import make_mount
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.trainer import Trainer


def main():
    bundle = registry.get("smollm-135m")
    cfg = bundle.smoke  # reduced config: runs on CPU in seconds
    run = bundle.run.replace(microbatch_per_data_shard=0, learning_rate=1e-3)

    # 1. storage: journaled xv6 behind the Bento typed boundary
    mf = make_mount("bento", n_blocks=32768)
    print(f"mounted {mf.mount.name} (generation {mf.mount.generation})")

    # 2. train with checkpoints through the fs
    t = Trainer(cfg, run, global_batch=8, seq_len=64,
                ckpt_view=mf.view, ckpt_every=5, seed=0)
    t.train(15)
    losses = [m["loss"] for m in t.metrics_log]
    print(f"loss {losses[0]:.3f} -> {losses[-1]:.3f} over {len(losses)} steps")
    print("checkpoints:", mf.view.listdir("/ckpt"))

    # 3. hot-upgrade the mounted fs (xv6 -> ext4like) without unmounting
    stats = upgrade(mf.mount, Ext4LikeFileSystem(),
                    migrate=lambda s, o, n: {**s, "dirindex": {}})
    print(f"online upgrade: {stats['total_s']*1e3:.1f} ms pause, "
          f"generation {mf.mount.generation}")
    assert mf.view.listdir("/ckpt")  # data survives

    # 4. serve greedily from the trained weights
    ctx = ShardingCtx.null()
    prefill = jax.jit(make_prefill_step(cfg, run, ctx))
    decode = jax.jit(make_decode_step(cfg, run, ctx))
    prompt = jnp.ones((1, 16), jnp.int32)
    tok, cache = prefill(t.params, {"tokens": prompt})
    cache = jax.tree.map(
        lambda x: jnp.pad(x, [(0, 0), (0, 0), (0, 8), (0, 0), (0, 0)])
        if x.ndim == 5 else x, cache)
    out = [int(tok[0])]
    for i in range(7):
        tok, cache = decode(t.params, cache,
                            {"tokens": tok[:, None], "pos": jnp.int32(16 + i)})
        out.append(int(tok[0]))
    print("generated:", out)
    mf.close()
    print("OK")


if __name__ == "__main__":
    main()
