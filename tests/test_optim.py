"""Optimizer + compression unit tests."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.base import RunConfig
from repro.distributed import compression as C
from repro.models import params as P
from repro.optim.adamw import (OptState, adamw_init_specs, adamw_update,
                               cosine_schedule)


def _setup(run: RunConfig, shape=(8, 8)):
    specs = {"w": P.dense(shape, (None, None)),
             "b": P.dense((shape[1],), (None,), init="zeros")}
    params = P.materialize(specs, jax.random.PRNGKey(0),
                           dtype=run.param_dtype)
    opt = P.materialize(adamw_init_specs(specs, run), jax.random.PRNGKey(1),
                        dtype="float32")
    return specs, params, opt


def test_adamw_minimizes_quadratic():
    run = RunConfig(learning_rate=0.05, weight_decay=0.0, grad_clip=0.0)
    _, params, opt = _setup(run)
    target = jax.random.normal(jax.random.PRNGKey(2), (8, 8))

    def loss_fn(p):
        return jnp.mean((p["w"] - target) ** 2) + jnp.mean(p["b"] ** 2)

    l0 = float(loss_fn(params))
    for _ in range(200):
        g = jax.grad(loss_fn)(params)
        params, opt, _ = adamw_update(g, params, opt, run)
    assert float(loss_fn(params)) < 0.01 * l0


def test_factored_second_moment_shapes():
    run = RunConfig(factored_second_moment=True)
    specs, params, opt = _setup(run, shape=(16, 32))
    nu_w = opt.nu["w"]
    assert set(nu_w) == {"_factored_row", "_factored_col"}
    assert nu_w["_factored_row"].shape == (16,)
    assert nu_w["_factored_col"].shape == (32,)
    g = jax.tree.map(jnp.ones_like, params)
    p2, o2, _ = adamw_update(g, params, opt, run)
    assert o2.nu["w"]["_factored_row"].shape == (16,)
    assert bool(jnp.all(jnp.isfinite(p2["w"])))


def test_master_weights_roundtrip():
    run = RunConfig(param_dtype="bfloat16", master_weights=True,
                    learning_rate=0.05, weight_decay=0.0)
    specs, params, opt = _setup(run)
    assert opt.master is not None
    assert opt.master["w"].dtype == jnp.float32
    assert params["w"].dtype == jnp.bfloat16
    # master must track updates at fp32 precision; params = cast(master)
    opt = OptState(opt.step, opt.mu, opt.nu,
                   jax.tree.map(lambda p: p.astype(jnp.float32), params))
    g = jax.tree.map(lambda p: 1e-3 * jnp.ones_like(p, jnp.float32), params)
    p2, o2, _ = adamw_update(g, params, opt, run)
    np.testing.assert_array_equal(
        np.asarray(p2["w"]), np.asarray(o2.master["w"].astype(jnp.bfloat16)))


def test_grad_clip_and_schedule():
    run = RunConfig(grad_clip=1.0)
    _, params, opt = _setup(run)
    g = jax.tree.map(lambda p: 1e6 * jnp.ones_like(p), params)
    _, _, stats = adamw_update(g, params, opt, run)
    assert float(stats["grad_norm"]) > 1e6  # reported pre-clip
    lr0 = cosine_schedule(jnp.int32(0), 1e-3)
    lr_mid = cosine_schedule(jnp.int32(200), 1e-3)
    lr_end = cosine_schedule(jnp.int32(10_000), 1e-3)
    assert float(lr0) < float(lr_mid)
    assert float(lr_end) < 1e-6 + 0.0 * float(lr_mid)


# --- compression -----------------------------------------------------------------


def test_int8_ef_reduces_bias_over_steps():
    x = jax.random.normal(jax.random.PRNGKey(0), (256,)) * 0.01
    r = jnp.zeros_like(x)
    # with error feedback, accumulated quantized sum converges to true sum
    acc_q = jnp.zeros_like(x)
    for _ in range(50):
        q, s, r = C.ef_compress_int8(x, r)
        acc_q += C.dequantize_int8(q, s)
    true = 50 * x
    rel = float(jnp.linalg.norm(acc_q - true) / jnp.linalg.norm(true))
    assert rel < 0.02, rel


def test_topk_ef_roundtrip():
    x = jax.random.normal(jax.random.PRNGKey(1), (128,))
    r = jnp.zeros_like(x)
    payload, r2 = C.ef_compress_topk(x, r, k_frac=0.1)
    dense = C.decompress_topk(payload, x.shape)
    # residual + decompressed == original
    np.testing.assert_allclose(np.asarray(dense + r2), np.asarray(x), atol=1e-6)


def test_compressed_psum_int8_single_shard():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import PartitionSpec as PS
    from repro.launch.mesh import make_host_mesh
    mesh = make_host_mesh(1, 1)
    x = jax.random.normal(jax.random.PRNGKey(2), (64,))
    f = shard_map(lambda v: C.compressed_psum_int8(v, "data"), mesh=mesh,
                  in_specs=PS(), out_specs=PS(), check_rep=False)
    y = f(x)
    np.testing.assert_allclose(np.asarray(y), np.asarray(x), atol=0.02)


def test_tree_compression_roundtrip():
    tree = {"a": jax.random.normal(jax.random.PRNGKey(3), (32,)),
            "b": {"c": jax.random.normal(jax.random.PRNGKey(4), (8, 8))}}
    res = C.init_residuals(tree)
    qs, scales, res2 = C.compress_tree_int8(tree, res)
    back = C.decompress_tree_int8(qs, scales)
    err = jax.tree.map(lambda x, y: float(jnp.max(jnp.abs(x - y))), tree, back)
    assert max(jax.tree.leaves(err)) < 0.05
