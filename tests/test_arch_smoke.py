"""Per-architecture smoke tests: every assigned arch instantiates a reduced
config of the same family and runs one forward/train step on CPU, asserting
output shapes and no NaNs (full configs are exercised via the dry-run)."""

import jax
import jax.numpy as jnp
import pytest

from repro.configs import registry
from repro.distributed.sharding import ShardingCtx
from repro.models import lm, params as P
from repro.optim.adamw import adamw_init_specs
from repro.train.step import make_train_step

ARCHS = registry.arch_ids()


def _batch(cfg, B=2, S=32):
    batch = {"tokens": jnp.ones((B, S), jnp.int32),
             "labels": jnp.ones((B, S), jnp.int32)}
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.02 * jnp.ones(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.bfloat16)
    if cfg.family == "audio":
        batch["frame_embeds"] = 0.02 * jnp.ones(
            (B, cfg.encoder_seq, cfg.d_model), jnp.bfloat16)
    return batch


@pytest.mark.parametrize("arch", ARCHS)
def test_forward_loss(arch):
    b = registry.get(arch)
    cfg = b.smoke
    ctx = ShardingCtx.null()
    prm = P.materialize(lm.param_specs(cfg), jax.random.PRNGKey(0))
    loss, metrics = jax.jit(
        lambda p, bb: lm.loss_fn(cfg, b.run, ctx, p, bb))(prm, _batch(cfg))
    assert loss.shape == ()
    assert bool(jnp.isfinite(loss)), f"{arch}: non-finite loss"
    assert metrics["nll"] > 0


@pytest.mark.parametrize("arch", ARCHS)
def test_train_step(arch):
    b = registry.get(arch)
    cfg = b.smoke
    run = b.run.replace(microbatch_per_data_shard=0)
    ctx = ShardingCtx.null()
    pspecs = lm.param_specs(cfg)
    prm = P.materialize(pspecs, jax.random.PRNGKey(0), dtype=run.param_dtype)
    opt = P.materialize(adamw_init_specs(pspecs, run), jax.random.PRNGKey(1),
                        dtype="float32")
    step = jax.jit(make_train_step(cfg, run, ctx, global_batch=2))
    p2, o2, m = step(prm, opt, _batch(cfg))
    assert bool(jnp.isfinite(m["loss"]))
    assert bool(m["grad_norm"] > 0), f"{arch}: zero gradient"
    # params actually changed
    l0 = jax.tree.leaves(prm)[0]
    l1 = jax.tree.leaves(p2)[0]
    assert l0.shape == l1.shape and l0.dtype == l1.dtype


@pytest.mark.parametrize("arch", ARCHS)
def test_param_count_positive(arch):
    b = registry.get(arch)
    n_full = b.model.param_count()
    n_active = b.model.active_param_count()
    assert n_full > 0 and 0 < n_active <= n_full
    if b.model.is_moe:
        assert n_active < n_full


def test_assigned_param_counts_plausible():
    """Exact spec counts should be in the ballpark of the published sizes."""
    expect = {
        "llama3-405b": (380e9, 430e9),
        "qwen1.5-110b": (95e9, 120e9),
        "rwkv6-7b": (6e9, 9e9),
        "zamba2-7b": (6e9, 9e9),
        "smollm-135m": (0.11e9, 0.16e9),
        "olmoe-1b-7b": (6e9, 8e9),
    }
    for arch, (lo, hi) in expect.items():
        n = registry.get(arch).model.param_count()
        assert lo < n < hi, f"{arch}: {n:.3e} outside [{lo:.1e},{hi:.1e}]"
