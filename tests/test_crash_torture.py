"""Crash-point torture sweeps on the ``repro.fs.crashsim`` harness.

Every test here enumerates device-write crash points (CrashMonkey-style)
instead of sampling them: the harness measures a workload's write
footprint, then re-runs it once per crash point with power loss injected,
remounts cold (``Journal.recover()``) and asserts an invariant.

The acceptance sweep — a linked create → write(PrevResult) → fsync chain
proven all-or-nothing at EVERY crash point on both xv6 and ext4like —
runs in tier-1 (it is small). The journal-pressure variant is the
regression tripwire for the chain-aware reservation itself: it is
calibrated so that the old per-member ``_begin_op`` reservation commits
MID-CHAIN (create durable without its write) and the sweep fails, which
was verified by disabling the chain hooks. Heavier corpora (multi-op
batches at scale, the checkpoint manifest chain exhaustively) are marked
``slow``; bounded subsets of them stay in tier-1.
"""

import pytest

from repro.core.interface import (Errno, PrevResult, ROOT_INO, SQE_LINK,
                                  SubmissionEntry)
from repro.fs.crashsim import (CrashSim, all_or_nothing, chain_workload,
                               quick_points, torture_chain, torture_dedup,
                               torture_dedup_churn, torture_fuse,
                               torture_lazy, torture_overlay,
                               torture_parallel, torture_prov,
                               torture_prov_chain, torture_rename)
from repro.fs.ext4like import Ext4LikeFileSystem
from repro.fs.xv6 import Xv6FileSystem, Xv6Options

FACTORIES = {
    "xv6": lambda: Xv6FileSystem(Xv6Options()),
    "ext4like": lambda: Ext4LikeFileSystem(),
    "xv6-vfs": lambda: Xv6FileSystem(Xv6Options(group_commit=False,
                                                batched_install=False)),
}


# --- the acceptance sweep: every crash point, both fs kinds ----------------------


@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_linked_chain_all_or_nothing_every_crash_point(kind):
    """EVERY device-write crash point of a create→write(PrevResult)→fsync
    chain leaves the file either fully present or fully absent after
    recovery — the chain-transaction guarantee, enumerated exhaustively."""
    points = torture_chain(kind, payload_blocks=2)
    assert points > 10  # the chain really hit the device


def test_quick_points_bounded_and_covers_edges():
    pts = quick_points(100, n=12)
    assert len(pts) <= 16
    assert {0, 1, 99, 100} <= set(pts)
    assert quick_points(5) == [0, 1, 2, 3, 4, 5]


# --- the regression tripwire: chain under journal pressure -----------------------


def test_chain_atomic_under_journal_pressure():
    """A chain submitted while ~14 unflushed journal blocks are pending
    (capacity 31): the old per-member ``_begin_op`` reservation hits its
    commit trigger BETWEEN the create and the write, committing a
    half-applied chain — with the chain hooks disabled this sweep fails at
    the crash point between those commits. Chain-aware reservation must
    keep every point all-or-nothing."""
    payload = b"C" * (2 * 4096 + 17)

    def setup(ctx):
        ctx.view.mkdir("/d1")
        ctx.view.mkdir("/d2")

    def workload(ctx):
        # pressure: unflushed 11-block write fills pending to ~14 of 31;
        # the chain's create (fresh dir block in /d2, nothing to absorb)
        # then pushes pending past the per-op reservation trigger
        ino = ctx.view.create("/d1/pressure").ino
        ctx.mount.call("write", ino, 0, b"P" * (11 * 4096))
        d2 = ctx.view.stat("/d2").ino
        comps = ctx.mount.submit([
            SubmissionEntry("create", (d2, "f"), user_data="c",
                            flags=SQE_LINK),
            SubmissionEntry("write", (PrevResult("ino"), 0, payload),
                            user_data="w", flags=SQE_LINK),
            SubmissionEntry("fsync", (PrevResult("ino", back=2),),
                            user_data="s"),
        ])
        assert all(c.ok for c in comps), \
            [(c.user_data, c.errno) for c in comps]
        assert ctx.fs.journal.chains >= 1  # chain scope really taken

    sim = CrashSim(FACTORIES["xv6"])
    sim.sweep(workload, all_or_nothing(payload, "/d2/f"), setup=setup)


def test_vfs_per_op_commit_chain_still_atomic():
    """The VFS-direct policy (commit at end of EVERY op) would naturally
    commit each chain member separately; in chain scope those commits
    defer to end_chain, so even this baseline gets all-or-nothing
    chains."""
    payload = b"V" * (3 * 4096 + 5)
    sim = CrashSim(FACTORIES["xv6-vfs"])
    sim.sweep(chain_workload(payload), all_or_nothing(payload))


# --- single ops and multi-op batches ---------------------------------------------


def test_single_op_overwrite_every_crash_point():
    """A single fsync'd overwrite is old XOR new at every crash point (the
    op-granular atomicity the chain work must not regress)."""
    old, new = b"O" * (2 * 4096), b"N" * (2 * 4096)

    def setup(ctx):
        ctx.view.write_file("/f", old)

    def workload(ctx):
        ctx.view.write_file("/f", new, create=False)
        ctx.view.fsync("/f")

    def invariant(rec):
        got = rec.view.read_file("/f")
        assert got in (old, new), f"torn overwrite: {len(got)}B"
        if not rec.crashed:
            assert got == new
        rec.view.statfs()

    CrashSim(FACTORIES["xv6"]).sweep(workload, invariant, setup=setup)


def test_multi_op_batch_commits_as_unit_every_crash_point():
    """An unchained write batch + flush stages everything in one open
    transaction: after a crash, either the whole batch is visible or none
    of it (group commit's atomicity, enumerated)."""
    old = {f"/f{i}": bytes([65 + i]) * 4096 for i in range(3)}
    new = {p: bytes([97 + i]) * 4096 for i, p in enumerate(old)}

    def setup(ctx):
        for p, data in old.items():
            ctx.view.write_file(p, data)

    def workload(ctx):
        ctx.view.write_many([(p, 0, d) for p, d in new.items()],
                            create=False, fsync=True)

    def invariant(rec):
        states = {p: rec.view.read_file(p) for p in old}
        if any(states[p] == new[p] for p in old):
            assert states == new, f"batch tore: {[len(v) for v in states.values()]}"
        else:
            assert states == old
        rec.view.listdir("/")

    CrashSim(FACTORIES["xv6"]).sweep(workload, invariant, setup=setup)


# --- rename-overwrite: old XOR new at every crash point --------------------------


@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_rename_overwrite_every_crash_point(kind):
    """The headline bugfix's crash story, enumerated exhaustively: a
    rename onto an existing name recovers to either the complete old
    mapping (target intact with ITS content, source still present) or the
    complete new one (source gone, target is the moved file, displaced
    blocks freed) — the target name always resolves, and free-block
    accounting matches the golden end states so a leak fails the sweep."""
    points = torture_rename(kind)
    assert points > 5  # the swap really hit the device


@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_rename_fresh_target_every_crash_point(kind):
    """Rename to a NOT-yet-existing name: after recovery exactly one of
    {old name, new name} resolves — never both, never neither — and the
    content is intact under whichever survived."""
    payload = b"R" * (2 * 4096 + 11)

    def setup(ctx):
        ctx.view.write_file("/old", payload)

    def workload(ctx):
        ctx.view.rename("/old", "/new")
        ctx.view.fsync("/new")

    def invariant(rec):
        old_e, new_e = rec.view.exists("/old"), rec.view.exists("/new")
        assert old_e != new_e, (
            f"rename tore: old={old_e} new={new_e} (both or neither)")
        name = "/old" if old_e else "/new"
        assert rec.view.read_file(name) == payload
        if not rec.crashed:
            assert new_e
        rec.view.statfs()

    CrashSim(FACTORIES[kind]).sweep(workload, invariant, setup=setup)


def test_rename_chained_manifest_swap_every_crash_point():
    """The checkpoint store's swap pattern as a raw chain: commit a tmp
    file, then rename it over the live name — at every crash point the
    live name resolves to EITHER the old or the new content, complete."""
    old, new = b"O" * (4096 + 100), b"N" * (2 * 4096 + 3)

    def setup(ctx):
        ctx.view.write_file("/live", old)

    def workload(ctx):
        ctx.view.write_file("/tmpf", new)
        ctx.view.fsync("/tmpf")
        ctx.view.rename("/tmpf", "/live")
        ctx.view.fsync("/live")

    def invariant(rec):
        got = rec.view.read_file("/live")
        assert got in (old, new), f"live name torn: {len(got)}B"
        if not rec.crashed:
            assert got == new
        rec.view.listdir("/")

    CrashSim(FACTORIES["xv6"]).sweep(workload, invariant, setup=setup)


# --- checkpoint re-save: the previous good checkpoint survives every point -------


def test_checkpoint_resave_never_loses_previous_good_checkpoint():
    """Re-saving over an existing checkpoint rides tmp-write + rename:
    at EVERY crash point latest_step still finds a parseable manifest —
    the old tree before the swap committed, the new one after. The old
    truncate-then-rewrite path had crash points where neither survived."""
    import numpy as np

    from repro.checkpoint import store

    tree_a = {"w": np.full((4, 4), 1.0, dtype=np.float32)}
    tree_b = {"w": np.full((4, 4), 2.0, dtype=np.float32)}

    def setup(ctx):
        store.save(ctx.view, "/ckpt/step_1", tree_a, step=1,
                   checksum=ctx.ks.checksum)

    def workload(ctx):
        store.save(ctx.view, "/ckpt/step_1", tree_b, step=1,
                   checksum=ctx.ks.checksum)

    def invariant(rec):
        step = store.latest_step(rec.view, "/ckpt")
        assert step == 1, "previous good checkpoint lost by a re-save crash"
        got, _ = store.load(rec.view, "/ckpt/step_1", tree_a,
                            checksum=rec.ks.checksum)
        a = np.asarray(got["w"])
        assert (a == 1.0).all() or (a == 2.0).all(), "manifest swap tore"
        if not rec.crashed:
            assert (a == 2.0).all()

    sim = CrashSim(FACTORIES["xv6"], n_blocks=4096)
    sim.sweep(workload, invariant, setup=setup)


# --- the provenance log: always explainable, record+mutation one txn -------------


@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_prov_log_explains_recovered_fs_every_crash_point(kind):
    """Power loss at EVERY device write of a mixed scalar workload through
    the provenance layer: replaying the recovered log's namespace records
    over the durable setup state reproduces the recovered tree EXACTLY —
    a record without its mutation, a mutation without its record, or a
    reorder all fail (the same-transaction guarantee, enumerated)."""
    assert torture_prov(kind) > 10


@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_prov_chain_txn_spans_data_and_records_every_crash_point(kind):
    """A linked create→write(PrevResult)→fsync chain under the layer:
    after recovery the file and its create/write records are durable
    together or not at all — one journal transaction spans the chain's
    data AND its provenance (the chain_begin extra_blocks reservation)."""
    assert torture_prov_chain(kind) > 10


def test_prov_layer_refuses_oversized_chain_with_record_padding():
    """A chain that fits the inner fs alone but NOT once the provenance
    reservation is added must be refused ENOSPC-first before staging —
    the record padding participates in the up-front atomicity check."""
    from repro.fs.crashsim import _prov_factory

    sim = CrashSim(_prov_factory("xv6"), nlog=16)  # capacity 15
    ctx = sim.boot(None)
    comps = ctx.mount.submit([
        SubmissionEntry("create", (ROOT_INO, "big"), user_data="c",
                        flags=SQE_LINK),
        SubmissionEntry("write", (PrevResult("ino"), 0, b"X" * (3 * 4096)),
                        user_data="w", flags=SQE_LINK),
        SubmissionEntry("fsync", (PrevResult("ino", back=2),),
                        user_data="s"),
    ])
    # inner estimate: create 6 + write (4+4) = 14 <= 15; with the record
    # padding it exceeds capacity and the whole chain is refused cleanly
    assert [c.errno for c in comps] == \
        [Errno.ENOSPC, Errno.ECANCELED, Errno.ECANCELED]
    assert not ctx.view.exists("/big")
    ctx.view.write_file("/ok", b"still serving")
    assert ctx.view.read_file("/ok") == b"still serving"
    assert ctx.fs.read_provenance()[-1]["op"] == "write"  # layer still logs


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_prov_log_torture_exhaustive_scaled(kind):
    """Scale variant of the prov sweep: more transactions, deeper tree."""
    from repro.fs.crashsim import _prov_factory

    def workload(ctx):
        v = ctx.view
        for i in range(6):
            v.create(f"/f{i}")
            v.write_file(f"/f{i}", bytes([65 + i]) * 2048, create=False)
            if i % 2 == 0:
                v.fsync(f"/f{i}")
        v.unlink("/f1")
        v.fsync("/f0")

    sim = CrashSim(_prov_factory(kind))

    def invariant(rec):
        recs = rec.fs.read_provenance()
        created = [r["name"] for r in recs if r["op"] == "create"]
        gone = {r["name"] for r in recs if r["op"] == "unlink"}
        got = set(rec.view.listdir("/"))
        assert got == set(created) - gone, (got, created, gone)
        for r in recs:  # every surviving create record maps name -> ino
            if r["op"] == "create" and r["name"] in got:
                assert rec.view.stat("/" + r["name"]).ino == r["ino"]

    sim.sweep(workload, invariant)


# --- the FUSE daemon's file-backed device (cross-process torture) ----------------


def test_fuse_daemon_chain_survives_power_loss_quick():
    """Power loss injected inside the daemon's FileBlockDevice, daemon
    SIGKILLed, backing file remounted by a fresh daemon: the chain must
    recover all-or-nothing across the address-space boundary too."""
    assert torture_fuse(quick=True) > 5


def test_fuse_daemon_detects_torn_write_quick():
    """Same sweep with the dying write TORN half-block: the journal's
    per-block checksums must reject the torn commit at recovery instead
    of installing garbage."""
    assert torture_fuse(quick=True, torn_bytes=2048) > 5


@pytest.mark.slow
def test_fuse_daemon_chain_every_crash_point():
    assert torture_fuse(quick=False) > 10


# --- chain overflow: ENOSPC before staging, never a raised JournalFull -----------


@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_chain_exceeding_journal_capacity_fails_clean(kind):
    """A chain whose footprint can never fit the journal (40-block write,
    capacity 31) completes ENOSPC-first/ECANCELED-rest with NOTHING staged
    and NO device write — never a raised JournalFull — and the fs keeps
    serving."""
    sim = CrashSim(FACTORIES[kind])
    ctx = sim.boot(None)
    w0 = ctx.dev.writes
    comps = ctx.mount.submit([
        SubmissionEntry("create", (ROOT_INO, "big"), user_data="c",
                        flags=SQE_LINK),
        SubmissionEntry("write", (PrevResult("ino"), 0, b"X" * (40 * 4096)),
                        user_data="w", flags=SQE_LINK),
        SubmissionEntry("fsync", (PrevResult("ino", back=2),),
                        user_data="s"),
    ])
    assert [c.errno for c in comps] == \
        [Errno.ENOSPC, Errno.ECANCELED, Errno.ECANCELED]
    assert len(ctx.fs.journal._pending) == 0  # nothing staged
    assert ctx.dev.writes == w0               # nothing hit the device
    assert not ctx.view.exists("/big")
    ctx.view.write_file("/ok", b"still serving")   # fs healthy after refusal
    assert ctx.view.read_file("/ok") == b"still serving"


# --- the checkpoint store's manifest chain ---------------------------------------


def _ckpt_roundtrip(points):
    """Sweep a full checkpoint save; after any crash the store shows
    either no checkpoint at all or a complete, checksum-clean one."""
    import numpy as np

    from repro.checkpoint import store

    tree = {"w": np.arange(48, dtype=np.float32).reshape(6, 8),
            "b": np.ones(16, dtype=np.float32)}

    def workload(ctx):
        store.save(ctx.view, "/ckpt/step_1", tree, step=1,
                   checksum=ctx.ks.checksum)

    def invariant(rec):
        step = store.latest_step(rec.view, "/ckpt")
        if step is None:
            assert rec.crashed, "no crash, yet the checkpoint is missing"
            return
        assert step == 1
        got, manifest = store.load(rec.view, "/ckpt/step_1", tree,
                                   checksum=rec.ks.checksum)
        assert manifest["step"] == 1
        for k in tree:
            np.testing.assert_array_equal(got[k], tree[k])

    sim = CrashSim(FACTORIES["xv6"], n_blocks=4096)
    sim.sweep(workload, invariant, quick=(points == "quick"))


def test_checkpoint_manifest_chain_quick_subset():
    _ckpt_roundtrip("quick")


def test_checkpoint_resave_with_shorter_manifest_parses():
    """Re-saving over an existing checkpoint with a SHORTER manifest must
    not leave stale tail bytes (write never truncates by itself) — the
    store clears the old manifest first, so json parses cleanly."""
    import numpy as np

    from repro.checkpoint import store

    ctx = CrashSim(FACTORIES["xv6"], n_blocks=4096).boot()
    tree = {"w": np.ones(8, dtype=np.float32)}
    store.save(ctx.view, "/ckpt/step_1", tree, step=1,
               checksum=ctx.ks.checksum, extra={"pad": "x" * 120})
    long_manifest = ctx.view.stat("/ckpt/step_1/manifest.json").size
    store.save(ctx.view, "/ckpt/step_1", tree, step=1,
               checksum=ctx.ks.checksum)        # shorter manifest
    assert ctx.view.stat("/ckpt/step_1/manifest.json").size < long_manifest
    assert store.latest_step(ctx.view, "/ckpt") == 1
    got, manifest = store.load(ctx.view, "/ckpt/step_1", tree,
                               checksum=ctx.ks.checksum)
    assert manifest["extra"] == {}
    np.testing.assert_array_equal(got["w"], tree["w"])


def test_checkpoint_manifest_bigger_than_journal_txn_still_saves():
    """A manifest whose JSON exceeds one journal transaction cannot ride
    the manifest chain (chains are bounded atomicity units, refused
    ENOSPC up front) — the store must fall back to an unchained write and
    the checkpoint must still round-trip."""
    import numpy as np

    from repro.checkpoint import store

    sim = CrashSim(FACTORIES["xv6"], n_blocks=4096, nlog=8)  # capacity 7
    ctx = sim.boot(None)
    tree = {"leaves": [np.full((1,), i, dtype=np.float32)
                       for i in range(96)]}   # manifest JSON > 1 block
    manifest = store.save(ctx.view, "/ckpt/step_1", tree, step=1,
                          checksum=ctx.ks.checksum)
    assert manifest["n_leaves"] == 96
    assert store.latest_step(ctx.view, "/ckpt") == 1
    got, _ = store.load(ctx.view, "/ckpt/step_1", tree,
                        checksum=ctx.ks.checksum)
    for i, leaf in enumerate(got["leaves"]):
        np.testing.assert_array_equal(leaf, tree["leaves"][i])


@pytest.mark.slow
def test_checkpoint_manifest_chain_every_crash_point():
    _ckpt_roundtrip("all")


# --- the v2 SHARDED save under power loss ----------------------------------------


def test_sharded_checkpoint_resave_old_xor_complete_new_quick_subset():
    """Power loss at a bounded subset of device writes of a v2 sharded
    RE-SAVE (a 2x2 grid leaf + an unsharded leaf over an existing
    checkpoint): at every point latest_step still finds the checkpoint,
    the live manifest names a complete 4-shard grid, the restored tree is
    entirely-old XOR entirely-new (mixed shard generations fail), and the
    no-crash control sees the new data (exhaustive behind --runslow)."""
    from repro.fs.crashsim import torture_ckpt_shards

    assert torture_ckpt_shards("xv6", quick=True) > 5


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_sharded_checkpoint_resave_every_crash_point(kind):
    from repro.fs.crashsim import torture_ckpt_shards

    assert torture_ckpt_shards(kind) > 20


# --- scale sweep (slow): mixed chained + unchained traffic -----------------------


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_mixed_batch_torture_exhaustive(kind):
    """Chains interleaved with unchained batches, fsyncs and deletes —
    every fsync'd chain all-or-nothing, every crash point."""
    payload = b"M" * (4 * 4096 + 9)

    def setup(ctx):
        ctx.view.mkdir("/d")
        ctx.view.write_file("/d/base", b"B" * 8192)

    def workload(ctx):
        d = ctx.view.stat("/d").ino
        ctx.view.write_many([("/d/base", 0, b"u" * 4096)], create=False)
        comps = ctx.mount.submit([
            SubmissionEntry("create", (d, "c1"), user_data=0,
                            flags=SQE_LINK),
            SubmissionEntry("write", (PrevResult("ino"), 0, payload),
                            user_data=1, flags=SQE_LINK),
            SubmissionEntry("fsync", (PrevResult("ino", back=2),),
                            user_data=2),
        ])
        assert all(c.ok for c in comps)
        ctx.view.unlink("/d/base")
        ctx.view.fsync("/d")

    def invariant(rec):
        if rec.view.exists("/d/c1"):
            assert rec.view.read_file("/d/c1") == payload
        rec.view.listdir("/d")
        rec.view.statfs()

    CrashSim(FACTORIES[kind], n_blocks=4096).sweep(
        workload, invariant, setup=setup)


# --- the dedup index: refcount-exact against the recovered metadata --------------


@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_dedup_index_refcount_exact_every_crash_point(kind):
    """Power loss at EVERY device write of a dup-heavy write → CoW
    overwrite → unlink sequence on a dedup mount: a full inode walk of
    the recovered image must agree with the dedup index block-for-block
    and count-for-count, the bitmap must equal reachability (no leaks,
    no double-frees), and every valid hash must match its block — index
    records journal in the same transaction as their cause, enumerated."""
    assert torture_dedup(kind) > 10


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_dedup_refcount_torture_exhaustive_scaled(kind):
    """Scale variant of the dedup sweep: chained batches carrying
    duplicate payloads, interleaved CoW overwrites, truncates and
    unlinks — the exhaustive index-refcount matrix behind --runslow."""
    from repro.fs.crashsim import _dedup_audit, _dedup_factory

    D = b"D" * 4096
    E = b"E" * 4096

    def setup(ctx):
        ctx.view.write_file("/seed", D + E + D)

    def workload(ctx):
        v = ctx.view
        # chained create→write triples, dup-heavy payloads (one journal
        # txn per pair; the dedup flush joins the chain transaction)
        v.create_and_write_many(
            [(f"/c{i}", D + E) for i in range(4)], fsync=True)
        v.write_file("/u", E + b"x" * 4096)     # partial dup
        v.fsync("/u")
        v.write_file("/c1", b"Y" * 4096, off=0, create=False)  # CoW break
        v.fsync("/c1")
        # truncate-to-zero really frees (partial truncate is lazy and
        # keeps blocks): every shared ref of /seed drops via release()
        v.truncate("/seed", 0)
        v.fsync("/seed")
        v.unlink("/c3")
        v.unlink("/u")
        v.fsync("/c0")

    sim = CrashSim(_dedup_factory(kind), nlog=64)
    assert sim.sweep(workload, _dedup_audit, setup=setup) > 50


# --- index compaction under churn: punch + remat crash-proven --------------------


@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_dedup_index_compaction_churn_quick_subset(kind):
    """Sustained create/delete churn drives the dedup index through a
    compaction PUNCH (fully-dead table block returned to the allocator)
    and a REMATERIALIZATION (a record landing on the punched hole), with
    the refcount-exact audit at a bounded crash-point subset. The golden
    run asserts both transitions fire — a sweep that never compacts
    proves nothing."""
    assert torture_dedup_churn(kind, quick=True) > 10


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_dedup_index_compaction_churn_every_crash_point(kind):
    assert torture_dedup_churn(kind) > 100


# --- concurrent lock domains: parallel drain vs serial, every power-loss point ---


@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_parallel_drain_byte_identical_quick_subset(kind):
    """One mutating chain + three read-only submitters on disjoint inode
    stripes, drained through the footprint-scheduled worker pool: at a
    bounded subset of power-loss points the recovered device image is
    byte-identical to the serial drain's and the chain stays
    all-or-nothing (CI smoke; exhaustive behind --runslow)."""
    assert torture_parallel(kind, quick=True) > 5


def test_parallel_drain_dedup_mount_quick_subset():
    """Same differential on a dedup mount, where every footprint carries
    the BLOCKSTORE domain: the degenerate fully-serialized schedule must
    also reproduce the serial drain's image at every sampled point."""
    assert torture_parallel("xv6", quick=True, dedup=True) > 5


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_parallel_drain_byte_identical_every_crash_point(kind):
    assert torture_parallel(kind) > 30


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_parallel_drain_dedup_every_crash_point(kind):
    assert torture_parallel(kind, dedup=True) > 30


# --- lazy materialization + CoW overlay, every power-loss point ------------------


@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_lazy_materialization_torture_quick_subset(kind):
    """Power loss inside the 2-step block fetch (data landing vs valid-bit
    commit): a half-materialized block must NEVER be visible — after
    remounting the SAME lazy device, base content reads back exactly
    (invalid blocks re-fetch from the provider) and the mutation chain
    stays all-or-nothing (CI smoke; exhaustive behind --runslow)."""
    assert torture_lazy(kind, quick=True) > 5


@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_overlay_tenant_torture_quick_subset(kind):
    """Whiteout, create-over-whiteout, copy-up overwrite and copy-up +
    rename on a CoW tenant, power loss at every sampled upper write: each
    merged name is old-XOR-new, no copy-up temp is ever visible, and the
    shared base image stays byte-identical (exhaustive behind --runslow)."""
    assert torture_overlay(kind, quick=True) > 5


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_lazy_materialization_torture_every_crash_point(kind):
    assert torture_lazy(kind) > 20


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["xv6", "ext4like"])
def test_overlay_tenant_torture_every_crash_point(kind):
    assert torture_overlay(kind) > 10
