import os
import sys

# Tests run on the single real CPU device (the dry-run's 512 fake devices are
# only set inside repro.launch.dryrun subprocesses, never here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks/

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)
