import os
import sys

# Tests run on the single real CPU device (the dry-run's 512 fake devices are
# only set inside repro.launch.dryrun subprocesses, never here).
sys.path.insert(0, os.path.join(os.path.dirname(__file__), ".."))  # benchmarks/

import numpy as np
import pytest


@pytest.fixture(scope="session")
def rng():
    return np.random.default_rng(0)


def pytest_addoption(parser):
    parser.addoption(
        "--runslow", action="store_true", default=False,
        help="run tests marked slow (exhaustive crash-torture sweeps)")


def pytest_configure(config):
    config.addinivalue_line(
        "markers",
        "slow: exhaustive sweep outside the tier-1 time budget "
        "(run with --runslow; CI covers a bounded subset)")


def pytest_collection_modifyitems(config, items):
    if config.getoption("--runslow"):
        return
    skip_slow = pytest.mark.skip(reason="slow sweep: needs --runslow")
    for item in items:
        if "slow" in item.keywords:
            item.add_marker(skip_slow)
