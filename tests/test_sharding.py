"""Sharding-rule resolution (pure-function tests with a stub mesh) and
dry-run smoke via subprocess (512 fake devices never touch this process)."""

import subprocess
import sys
import os

import pytest

from repro.configs import registry
from repro.distributed.sharding import BASELINE, RULESETS, resolve_spec
from repro.models import lm, params as P


class StubMesh:
    """Looks enough like a jax Mesh for resolve_spec (pure function)."""

    def __init__(self, shape, names):
        import numpy as np
        self.devices = np.empty(shape)
        self.axis_names = names


MESH_1POD = StubMesh((16, 16), ("data", "model"))
MESH_2POD = StubMesh((2, 16, 16), ("pod", "data", "model"))


def test_divisibility_fallback():
    # 9 heads can't shard over model=16 -> unsharded
    spec = resolve_spec(("batch", "seq", "heads", "head_dim"),
                        (256, 4096, 9, 64), MESH_1POD, BASELINE)
    assert spec == __import__("jax").sharding.PartitionSpec("data")
    # 128 heads shard fine
    spec = resolve_spec(("batch", "seq", "heads", "head_dim"),
                        (256, 4096, 128, 64), MESH_1POD, BASELINE)
    assert tuple(spec) == ("data", None, "model")


def test_no_axis_reuse_within_spec():
    # vocab and fsdp both want axes; each mesh axis used at most once
    spec = resolve_spec(("vocab", "fsdp"), (128256, 16384), MESH_1POD, BASELINE)
    axes = [a for a in tuple(spec) if a is not None]
    flat = []
    for a in axes:
        flat.extend(a if isinstance(a, tuple) else (a,))
    assert len(flat) == len(set(flat))


def test_pod_axis_joins_batch():
    spec = resolve_spec(("batch", "seq"), (256, 4096), MESH_2POD, BASELINE)
    assert tuple(spec)[0] == ("pod", "data")


def test_all_arch_param_specs_resolve():
    """Every arch's full param tree resolves under every ruleset and both
    production mesh shapes without error."""
    for arch in registry.arch_ids():
        cfg = registry.get(arch).model
        specs = lm.param_specs(cfg)
        leaves = __import__("jax").tree.leaves(specs, is_leaf=P.is_spec)
        for mesh in (MESH_1POD, MESH_2POD):
            for name, rules in RULESETS.items():
                for s in leaves:
                    resolve_spec(s.logical, s.shape, mesh, rules)


@pytest.mark.slow
def test_dryrun_smoke_subprocess():
    """The real dry-run path: reduced config x 512 fake devices, both
    meshes, in a subprocess so this process keeps 1 device."""
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run(
        [sys.executable, "-m", "repro.launch.dryrun", "--arch", "smollm-135m",
         "--shape", "train_4k", "--mesh", "both", "--smoke",
         "--out", "/tmp/dryrun_pytest"],
        env=env, capture_output=True, text=True, timeout=900)
    assert out.returncode == 0, out.stdout[-2000:] + out.stderr[-2000:]
    assert out.stdout.count("OK ") == 2, out.stdout[-2000:]
