"""The Bento safety contracts: capabilities are unforgeable, borrows are
mutable-xor-shared, buffers cannot leak silently, the op gate quiesces."""

import pickle
import threading
import time

import pytest

try:  # optional: property tests skip cleanly when hypothesis is absent
    import hypothesis as hp
    import hypothesis.strategies as st
    HAVE_HYPOTHESIS = True
except ImportError:
    HAVE_HYPOTHESIS = False

from repro.core.capability import (CapabilityError, SuperBlockCap,
                                   mint_metrics, mint_superblock)
from repro.core.ownership import Borrow, BorrowError, Owned
from repro.core.registry import OpGate
from repro.core.services import kernel_binding
from repro.fs.blockdev import MemBlockDevice
from repro.fs.buffercache import BufferCache, BufferLeak


class _Sb:
    block_size, n_blocks, device_id = 4096, 64, "t"


def test_capability_cannot_be_forged():
    with pytest.raises(CapabilityError):
        SuperBlockCap(_Sb())
    cap = mint_superblock(_Sb())
    assert cap.block_size == 4096


def test_capability_cannot_be_pickled_or_copied():
    import copy
    cap = mint_superblock(_Sb())
    with pytest.raises(CapabilityError):
        pickle.dumps(cap)
    with pytest.raises(CapabilityError):
        copy.deepcopy(cap)


def test_capability_revocation():
    cap = mint_superblock(_Sb())
    cap._revoke()
    with pytest.raises(CapabilityError):
        _ = cap.n_blocks


def test_services_require_capability():
    ks = kernel_binding(MemBlockDevice(64))
    with pytest.raises(CapabilityError):
        ks.sb_bread(object(), 0)  # a forged "superblock"
    bh = ks.sb_bread(ks.superblock(), 0)
    bh.brelse()


# --- ownership / borrows -------------------------------------------------------


def test_borrow_rules():
    o = Owned([1, 2, 3], name="obj")
    b1 = o.borrow()
    b2 = o.borrow()  # many shared borrows OK
    with pytest.raises(BorrowError):
        o.borrow_mut()  # not while shared
    b1.end()
    b2.end()
    with o.borrow_mut() as m:
        m.set([4])
        with pytest.raises(BorrowError):
            o.borrow()  # not while mutably lent
    assert o.take() == [4]


def test_use_after_return_raises():
    o = Owned("x")
    b = o.borrow()
    b.end()
    with pytest.raises(BorrowError):
        b.get()


def test_take_while_lent_raises():
    o = Owned("x")
    b = o.borrow()
    with pytest.raises(BorrowError):
        o.take()  # paper §3.2.1: upgrade must wait for returns
    b.end()
    assert o.take() == "x"


@pytest.mark.skipif(not HAVE_HYPOTHESIS, reason="hypothesis not installed")
def test_borrow_state_machine():
    """Fuzz: Owned must behave exactly like the reference borrow model
    (shared* XOR mutable)."""

    @hp.given(st.lists(st.sampled_from(["s", "m", "end"]), max_size=40))
    @hp.settings(max_examples=60, deadline=None)
    def run(script):
        _check_borrow_script(script)

    run()


def _check_borrow_script(script):
    o = Owned(0)
    live = []  # list of (kind, borrow)
    for action in script:
        kinds = [k for k, _ in live]
        if action == "s":
            if "mu" in kinds:
                with pytest.raises(BorrowError):
                    o.borrow()
            else:
                live.append(("sh", o.borrow()))
        elif action == "m":
            if kinds:
                with pytest.raises(BorrowError):
                    o.borrow_mut()
            else:
                live.append(("mu", o.borrow_mut()))
        elif action == "end" and live:
            _, b = live.pop()
            b.end()
    assert o.is_lent == bool(live)


# --- buffer cache drop semantics --------------------------------------------------


def test_bufferhead_use_after_brelse():
    cache = BufferCache(MemBlockDevice(16))
    bh = cache.bread(1)
    bh.brelse()
    with pytest.raises(BufferLeak):
        bh.data()


def test_buffer_leak_detected_at_teardown():
    cache = BufferCache(MemBlockDevice(16))
    bh = cache.bread(2)
    with pytest.raises(BufferLeak):
        cache.assert_no_leaks()
    bh.brelse()
    cache.assert_no_leaks()


def test_drop_releases():
    cache = BufferCache(MemBlockDevice(16))
    bh = cache.bread(3)
    del bh  # drop -> brelse (paper §4.7)
    cache.assert_no_leaks()


# --- op gate (quiesce) ---------------------------------------------------------------


def test_opgate_quiesces_inflight_ops():
    gate = OpGate()
    entered = threading.Event()
    release = threading.Event()
    done = threading.Event()

    def op():
        gate.enter()
        entered.set()
        release.wait(5)
        gate.exit()
        done.set()

    t = threading.Thread(target=op, daemon=True)
    t.start()
    entered.wait(5)
    frozen = threading.Event()

    def freezer():
        gate.freeze()
        frozen.set()

    f = threading.Thread(target=freezer, daemon=True)
    f.start()
    time.sleep(0.05)
    assert not frozen.is_set()  # freeze must wait for the in-flight op
    release.set()
    assert done.wait(5)
    assert frozen.wait(5)
    # new ops blocked while frozen
    blocked = threading.Event()

    def late_op():
        gate.enter()
        blocked.set()
        gate.exit()

    t2 = threading.Thread(target=late_op, daemon=True)
    t2.start()
    time.sleep(0.05)
    assert not blocked.is_set()
    gate.thaw()
    assert blocked.wait(5)


def test_metrics_capability_append_only():
    sink = []
    cap = mint_metrics(sink)
    cap.emit("loss", 1.5, step=3)
    assert sink == [("loss", 1.5, 3)]
