"""BufferHead finalizer-race regressions (paper §4.7 drop semantics).

The pre-fix cache had three holes around the ``__del__``/``brelse`` race:
a double release could decrement a refcount twice, a finalizer running
after ``invalidate()`` minted a NEGATIVE refs entry (which could silently
cancel a real +1 leak on the same block), and a finalizer firing during
interpreter/cache teardown sprayed "Exception ignored in __del__" noise.
These tests pin the idempotent-release protocol that closed them.
"""

import gc

import pytest

from repro.fs.blockdev import MemBlockDevice
from repro.fs.buffercache import BufferCache, BufferLeak


def test_dropped_unreleased_head_unpins_cleanly():
    cache = BufferCache(MemBlockDevice(16))
    bh = cache.bread(4)
    bh.mark_dirty()
    del bh  # drop -> brelse, including the dirty writeback
    gc.collect()
    cache.assert_no_leaks()
    assert cache._refs == {}


def test_double_release_never_goes_negative():
    """brelse twice + the GC finalizer afterwards: exactly one unpin."""
    cache = BufferCache(MemBlockDevice(16))
    bh = cache.bread(5)
    other = cache.bread(5)  # second pin keeps the refs entry observable
    bh.brelse()
    bh.brelse()
    bh.__del__()  # the finalizer racing an explicit brelse
    assert cache._refs[5] == 1, "double release decremented twice"
    other.brelse()
    cache.assert_no_leaks()


def test_brelse_many_skips_already_released_heads():
    cache = BufferCache(MemBlockDevice(16))
    heads = cache.bread_many([1, 2, 3])
    heads[1].brelse()
    cache.brelse_many(heads)  # one head already gone — must not double-unpin
    cache.assert_no_leaks()
    assert cache._refs == {}


def test_finalizer_after_invalidate_mints_no_negative_entry():
    """A head outliving ``invalidate()`` unpins to NOTHING. Pre-fix it
    wrote refs[b] = -1, which a later un-released bread of the same block
    would cancel back to 0 — masking a real leak from the detector."""
    cache = BufferCache(MemBlockDevice(16))
    stale = cache.bread(7)
    cache.invalidate()  # drops the refs table wholesale
    del stale  # finalizer fires with no refs entry behind it
    gc.collect()
    assert 7 not in cache._refs
    leaked = cache.bread(7)  # new pin, never released
    with pytest.raises(BufferLeak, match="7"):
        cache.assert_no_leaks()
    leaked.brelse()
    cache.assert_no_leaks()


def test_finalizer_survives_cache_teardown():
    """__del__ during interpreter shutdown can find the cache (or its
    lock) already torn down; it must swallow, not spray 'Exception
    ignored' noise."""
    cache = BufferCache(MemBlockDevice(16))
    bh = cache.bread(8)

    def boom(_bh):
        raise RuntimeError("lock is gone")

    cache._release = boom
    bh.__del__()  # must not raise
    assert bh._held  # the unpin genuinely did not happen
    del cache._release  # restore the real method
    bh.brelse()
    cache.assert_no_leaks()


def test_bread_many_failure_strands_no_pins():
    """All-or-nothing bulk read: when the device run fails, the warm
    prefix already pinned must unpin before the error propagates."""
    dev = MemBlockDevice(16)
    cache = BufferCache(dev)
    cache.bread(0).brelse()  # warm one block

    def fail(_blocknos):
        raise IOError("device gone")

    dev.read_many = fail
    with pytest.raises(IOError, match="device gone"):
        cache.bread_many([0, 1, 2])
    cache.assert_no_leaks()
    assert cache._refs == {}
