"""The BentoQueue batched boundary: submission ordering, per-entry errno
isolation, one gate-crossing / one checksum-launch per batch, reentrancy
during quiesce, and upgrade-during-inflight-batch atomicity (§4.8 extended
to batches)."""

import threading
import time

import pytest

from repro.core.interface import (BATCHABLE_OPS, CompletionEntry, Errno,
                                  FsError, PrevResult, SQE_DRAIN, SQE_LINK,
                                  SubmissionEntry, split_chains)
from repro.core.registry import BentoQueue, OpGate
from repro.core.upgrade import UpgradeError, transfer_state, upgrade
from repro.fs.mounts import make_mount
from repro.fs.xv6 import Xv6FileSystem, Xv6Options


@pytest.fixture(params=["bento", "vfs", "ext4like"])
def mounted(request):
    mf = make_mount(request.param, n_blocks=8192)
    yield mf
    mf.close()


# --- ordering + isolation ------------------------------------------------------


def test_completions_in_submission_order(mounted):
    v = mounted.view
    v.makedirs("/d")
    v.write_file("/d/f", b"0123456789" * 100)
    ino = v.stat("/d/f").ino
    dino = v.stat("/d").ino
    entries = [
        SubmissionEntry("read", (ino, 0, 4), user_data=0),
        SubmissionEntry("getattr", (ino,), user_data=1),
        SubmissionEntry("lookup", (dino, "f"), user_data=2),
        SubmissionEntry("write", (ino, 0, b"ABCD"), user_data=3),
        SubmissionEntry("read", (ino, 0, 4), user_data=4),
        SubmissionEntry("statfs", (), user_data=5),
    ]
    comps = mounted.mount.submit(entries)
    assert [c.user_data for c in comps] == [0, 1, 2, 3, 4, 5]
    assert all(c.ok for c in comps)
    assert comps[0].result == b"0123"
    assert comps[4].result == b"ABCD"  # sees the write earlier in the batch


def test_per_entry_errno_isolation(mounted):
    """One failing entry must not poison the batch — and the error crosses
    the boundary as an errno value, not an exception."""
    v = mounted.view
    v.write_file("/ok", b"fine")
    ino = v.stat("/ok").ino
    comps = mounted.mount.submit([
        SubmissionEntry("write", (ino, 0, b"AA"), user_data="w1"),
        SubmissionEntry("read", (123456, 0, 4), user_data="bad-ino"),
        SubmissionEntry("lookup", (ino, "x"), user_data="not-dir"),
        SubmissionEntry("frobnicate", (), user_data="bad-op"),
        SubmissionEntry("read", (ino, 0, 4), user_data="w2"),
    ])
    by_ud = {c.user_data: c for c in comps}
    assert by_ud["w1"].ok and by_ud["w1"].result == 2
    assert by_ud["bad-ino"].errno in (Errno.ESTALE, Errno.ENOENT)
    assert by_ud["not-dir"].errno == Errno.ENOTDIR
    assert by_ud["bad-op"].errno == Errno.EINVAL
    assert by_ud["w2"].ok and by_ud["w2"].result == b"AAne"
    with pytest.raises(FsError):
        by_ud["bad-op"].unwrap()


def test_malformed_args_become_einval(mounted):
    v = mounted.view
    v.write_file("/m", b"mm")
    ino = v.stat("/m").ino
    comps = mounted.mount.submit([
        SubmissionEntry("read", (1,), user_data=0),       # missing off/size
        SubmissionEntry("read", (ino, 0, 2.5), user_data=1),  # float size
        SubmissionEntry("read", (ino, 0.0, 2), user_data=2),  # float off
        SubmissionEntry("statfs", (), user_data=3),
    ])
    assert comps[0].errno == Errno.EINVAL
    assert comps[1].errno == Errno.EINVAL
    assert comps[2].errno == Errno.EINVAL
    assert comps[3].ok


def test_malformed_write_payload_isolated_on_every_fs(mounted):
    """A write entry whose payload isn't bytes must complete with EINVAL on
    every implementation (incl. ext4like's coalescing path), never raise."""
    v = mounted.view
    v.write_file("/t", b"base")
    ino = v.stat("/t").ino
    comps = mounted.mount.submit([
        SubmissionEntry("write", (ino, 0, 123), user_data="int-payload"),
        SubmissionEntry("write", (5,), user_data="short-args"),
        SubmissionEntry("write", (ino, 0, b"OK"), user_data="good"),
    ])
    assert [c.user_data for c in comps] == ["int-payload", "short-args", "good"]
    assert comps[0].errno == Errno.EINVAL
    assert comps[1].errno == Errno.EINVAL
    assert comps[2].ok and v.read_file("/t") == b"OKse"


def test_kwargs_entries_work_on_concrete_fs(mounted):
    """BentoQueue.prep-style keyword entries must not be broken by the
    run-coalescing fast path."""
    v = mounted.view
    v.write_file("/k", b"kwargs!")
    ino = v.stat("/k").ino
    comps = mounted.mount.submit([
        SubmissionEntry("read", (ino,), {"off": 0, "size": 6}, "kw"),
        SubmissionEntry("read", (ino, 0, 6), user_data="pos"),
        SubmissionEntry("write", (ino,), {"off": 0, "data": b"KWARGS"}, "kww"),
    ])
    assert comps[0].ok and comps[0].result == b"kwargs"
    assert comps[1].ok and comps[1].result == b"kwargs"
    assert comps[2].ok and comps[2].result == 6
    assert v.read_file("/k") == b"KWARGS!"


def test_posix_nonstrict_isolates_walk_failures(mounted):
    """strict=False: a missing path comes back as an in-list FsError and
    the valid entries still complete (the docstring's contract)."""
    v = mounted.view
    v.write_file("/have", b"data")
    got = v.read_many(["/missing", "/have", ("/missing", 0, 2)], strict=False)
    assert isinstance(got[0], FsError) and got[0].errno == Errno.ENOENT
    assert got[1] == b"data"
    assert isinstance(got[2], FsError)
    st = v.stat_many(["/have", "/missing"], strict=False)
    assert st[0].size == 4 and isinstance(st[1], FsError)
    wr = v.write_many([("/no/such/dir/f", b"x"), ("/have", 0, b"DATA")],
                      strict=False)
    assert isinstance(wr[0], FsError) and wr[1] == 4
    assert v.read_file("/have") == b"DATA"
    with pytest.raises(FsError):
        v.read_many(["/missing"])  # strict default still raises


def test_ext4like_lookup_many_counts_per_entry():
    mf = make_mount("ext4like", n_blocks=4096)
    v = mf.view
    v.makedirs("/d")
    for c in "abc":
        v.write_file(f"/d/{c}", b"x")
    fs = mf.mount.module
    dino = v.stat("/d").ino
    ops0 = fs.stats["ops"]
    fs.lookup_many([(dino, "a"), (dino, "b"), (dino, "c")])
    assert fs.stats["ops"] - ops0 == 3
    mf.close()


def test_device_error_mid_batch_is_per_entry_eio_and_leaks_nothing():
    """A device error during the bulk cache pass must complete the batch's
    reads with EIO (no exception across the boundary) and release every
    buffer ref (unmount's leak detector is the proof)."""
    mf = make_mount("bento", n_blocks=2048)
    v = mf.view
    v.write_file("/f", b"x" * 8192)
    v.fsync("/f")
    ino = v.stat("/f").ino
    fs = mf.mount.module
    fs._iget(ino).addrs[0] = 999999  # corrupt: points past the device
    comps = mf.mount.submit([
        SubmissionEntry("read", (ino, 0, 4096), user_data="bad-block"),
        SubmissionEntry("read", (ino, 4096, 4096), user_data="same-run"),
        SubmissionEntry("getattr", (ino,), user_data="next-run"),
    ])
    assert comps[0].errno == Errno.EIO
    assert comps[1].errno == Errno.EIO  # same bulk pass: attribution is EIO
    assert comps[2].ok                  # later run unaffected
    fs._iget(ino).addrs[0] = 0          # un-corrupt so unmount flushes clean
    mf.close()                          # assert_no_leaks fires here if stranded


def test_fuse_bridge_batched_round_trip():
    """The FUSE daemon speaks the batched boundary: entry/completion
    records pickle across the socket, one round-trip per batch, per-entry
    errno isolation intact, fsync/flush entries trigger the device sync."""
    mf = make_mount("fuse", n_blocks=2048)
    v = mf.view
    v.write_file("/f", b"fusebatch")
    ino = v.stat("/f").ino
    comps = mf.mount.submit([
        SubmissionEntry("read", (ino, 0, 4), user_data="r"),
        SubmissionEntry("read", (424242, 0, 4), user_data="bad"),
        SubmissionEntry("write", (ino, 0, b"FUSE"), user_data="w"),
        SubmissionEntry("flush", (), user_data="f"),
    ])
    assert [c.user_data for c in comps] == ["r", "bad", "w", "f"]
    assert comps[0].ok and comps[0].result == b"fuse"
    assert not comps[1].ok and comps[1].errno is not None
    assert comps[2].ok and comps[2].result == 4
    assert v.read_file("/f") == b"FUSEbatch"
    assert v.read_many([("/f", 0, 9)]) == [b"FUSEbatch"]
    assert v.write_many([("/f", 4, b"BATCH")], fsync=True) == [5]
    mf.close()


def test_batchable_ops_exclude_lifecycle():
    assert "init" not in BATCHABLE_OPS
    assert "destroy" not in BATCHABLE_OPS
    assert "submit_batch" not in BATCHABLE_OPS  # no nesting


# --- gate-crossing + checksum amortization -------------------------------------


def test_one_gate_crossing_per_batch():
    mf = make_mount("bento", n_blocks=4096)
    v = mf.view
    v.write_file("/f", b"x" * 65536)
    ino = v.stat("/f").ino
    gate = mf.mount.gate
    g0 = gate.crossings
    mf.mount.submit([SubmissionEntry("read", (ino, i * 4096, 4096))
                     for i in range(16)])
    assert gate.crossings - g0 == 1
    g0 = gate.crossings
    for i in range(16):
        mf.mount.call("read", ino, i * 4096, 4096)
    assert gate.crossings - g0 == 16
    mf.close()


def test_one_checksum_batch_launch_per_flushed_batch():
    """A batch of writes + flush commits as ONE journal transaction: one
    checksum_batch call (one Pallas launch in the kernel binding)."""
    mf = make_mount("bento", n_blocks=4096)
    v = mf.view
    v.write_file("/f", b"z" * (64 * 4096))
    v.fsync("/f")
    ks = mf.services
    for _ in range(3):
        c0 = ks.counters["checksum_batch_calls"]
        items = [("/f", i * 4096, b"w" * 4096) for i in range(8)]
        v.write_many(items, create=False, fsync=True)
        assert ks.counters["checksum_batch_calls"] - c0 == 1
    mf.close()


def test_bulk_bread_used_by_batched_reads():
    mf = make_mount("bento", n_blocks=4096)
    v = mf.view
    v.write_file("/f", b"r" * (32 * 4096))
    v.fsync("/f")
    ks = mf.services
    b0 = ks.counters["bread_many_calls"]
    v.read_many([("/f", i * 4096, 4096) for i in range(32)])
    assert ks.counters["bread_many_calls"] - b0 == 1
    mf.close()


# --- chained SQEs (SQE_LINK / ECANCELED / PrevResult) ---------------------------


def test_chain_failure_cancels_remaining_members(mounted):
    """io_uring link rule: entry N+1 runs only if entry N succeeded; the
    first failure completes the rest of ITS chain with ECANCELED while
    entries outside the chain are untouched."""
    v = mounted.view
    v.write_file("/pre", b"data")
    ino = v.stat("/pre").ino
    comps = mounted.mount.submit([
        SubmissionEntry("create", (1, "pre"), user_data="c1",
                        flags=SQE_LINK),                     # EEXIST
        SubmissionEntry("write", (ino, 0, b"NO"), user_data="w1",
                        flags=SQE_LINK),                     # cancelled
        SubmissionEntry("getattr", (ino,), user_data="g1"),  # chain tail
        SubmissionEntry("read", (ino, 0, 4), user_data="r-outside"),
    ])
    by = {c.user_data: c for c in comps}
    assert by["c1"].errno == Errno.EEXIST
    assert by["w1"].errno == Errno.ECANCELED
    assert by["g1"].errno == Errno.ECANCELED
    assert by["r-outside"].ok and by["r-outside"].result == b"data"
    assert v.read_file("/pre") == b"data"  # cancelled write never ran


def test_chain_prev_result_feeds_created_ino(mounted):
    v = mounted.view
    comps = mounted.mount.submit([
        SubmissionEntry("create", (1, "cf"), user_data="c",
                        flags=SQE_LINK),
        SubmissionEntry("write", (PrevResult("ino"), 0, b"chained!"),
                        user_data="w", flags=SQE_LINK),
        SubmissionEntry("fsync", (PrevResult("ino", back=2),),
                        user_data="s"),
    ])
    assert all(c.ok for c in comps)
    assert comps[1].result == 8
    assert v.read_file("/cf") == b"chained!"


def test_prev_result_outside_chain_or_out_of_range_is_einval(mounted):
    v = mounted.view
    v.write_file("/x", b"x")
    comps = mounted.mount.submit([
        SubmissionEntry("getattr", (PrevResult("ino"),), user_data="stray"),
        SubmissionEntry("create", (1, "ok1"), user_data="c",
                        flags=SQE_LINK),
        SubmissionEntry("write", (PrevResult("ino", back=9), 0, b"z"),
                        user_data="bad-back", flags=SQE_LINK),
        SubmissionEntry("getattr", (1,), user_data="cancelled"),
    ])
    by = {c.user_data: c for c in comps}
    assert by["stray"].errno == Errno.EINVAL      # no chain to resolve from
    assert by["c"].ok                             # create itself fine
    assert by["bad-back"].errno == Errno.EINVAL   # back escapes the chain
    assert by["cancelled"].errno == Errno.ECANCELED


def test_bento_queue_defers_auto_submit_mid_chain():
    mf = make_mount("bento", n_blocks=4096)
    q = BentoQueue(mf.mount, depth=2)
    q.prep("create", 1, "qa", user_data="c", flags=SQE_LINK)
    q.prep("write", PrevResult("ino"), 0, b"Q", user_data="w")
    # depth hit at the LINK entry must not sever the chain
    assert len(q) == 0 or len(q) == 2  # either all submitted at tail, or staged
    q.submit()
    comps = q.drain()
    assert [c.user_data for c in comps] == ["c", "w"]
    assert all(c.ok for c in comps)
    assert mf.view.read_file("/qa") == b"Q"
    mf.close()


# --- SQE_DRAIN barriers (IOSQE_IO_DRAIN analogue) -------------------------------


def test_drain_splits_groups_never_severs_chains():
    e = lambda flags=0: SubmissionEntry("statfs", (), flags=flags)
    groups = split_chains([e(), e(), e(SQE_DRAIN), e()])
    assert [(c, len(g)) for c, g in groups] == [(False, 2), (False, 2)]
    # a drain on a LATER chain member never severs the chain
    groups = split_chains([e(SQE_LINK), e(SQE_LINK | SQE_DRAIN), e()])
    assert [(c, len(g)) for c, g in groups] == [(True, 3)]
    # drain entry heading the batch is just a normal group start
    groups = split_chains([e(SQE_DRAIN), e()])
    assert [(c, len(g)) for c, g in groups] == [(False, 2)]


def test_drain_barrier_splits_coalesced_runs():
    """The observable barrier: a module's same-op coalescing (one bulk
    bread per read run) must not cross a drain — two runs, two bulk
    passes; without the flag the same batch is one pass."""
    mf = make_mount("bento", n_blocks=4096)
    v = mf.view
    v.write_file("/f", b"r" * (8 * 4096))
    v.fsync("/f")
    ino = v.stat("/f").ino
    ks = mf.services

    def batch(drain):
        return [SubmissionEntry(
            "read", (ino, i * 4096, 4096), user_data=i,
            flags=SQE_DRAIN if (drain and i == 4) else 0) for i in range(8)]

    b0 = ks.counters["bread_many_calls"]
    comps = mf.mount.submit(batch(drain=False))
    assert all(c.ok for c in comps)
    assert ks.counters["bread_many_calls"] - b0 == 1
    b0 = ks.counters["bread_many_calls"]
    comps = mf.mount.submit(batch(drain=True))
    assert [c.user_data for c in comps] == list(range(8))
    assert all(c.ok for c in comps)
    assert ks.counters["bread_many_calls"] - b0 == 2  # split at the barrier
    mf.close()


def test_drain_entry_runs_after_failed_chain(mounted):
    """A drain entry is OUTSIDE any chain: a failing chain before it
    cancels its own members, then the drain entry executes normally —
    'run after everything prior completed, whatever its fate'."""
    v = mounted.view
    v.write_file("/pre", b"data")
    ino = v.stat("/pre").ino
    comps = mounted.mount.submit([
        SubmissionEntry("create", (1, "pre"), user_data="c",
                        flags=SQE_LINK),                    # EEXIST
        SubmissionEntry("write", (ino, 0, b"NO"), user_data="w"),  # tail
        SubmissionEntry("read", (ino, 0, 4), user_data="drained",
                        flags=SQE_DRAIN),
    ])
    by = {c.user_data: c for c in comps}
    assert by["c"].errno == Errno.EEXIST
    assert by["w"].errno == Errno.ECANCELED
    assert by["drained"].ok and by["drained"].result == b"data"


def test_posix_fsync_flush_is_drain_flagged(mounted):
    """write_many(fsync=True): the trailing flush rides a drain barrier —
    behaviour identical, ordering documented (and the flush commits the
    batch exactly once)."""
    v = mounted.view
    v.write_file("/df", b"0" * 8192)
    assert v.write_many([("/df", 0, b"1" * 4096), ("/df", 4096, b"2" * 4096)],
                        create=False, fsync=True) == [4096, 4096]
    assert v.read_file("/df") == b"1" * 4096 + b"2" * 4096


# --- chain-aware journal reservation ---------------------------------------------


def _tiny_journal_mount(nlog=8, n_blocks=2048, fs_cls=None):
    """Cold boot over a tiny journal, via the crash harness's canonical
    boot path (one copy of the device+mkfs+mount recipe in the tree)."""
    from repro.fs.crashsim import CrashSim

    sim = CrashSim(lambda: (fs_cls or Xv6FileSystem)(Xv6Options()),
                   n_blocks=n_blocks, ninodes=64, nlog=nlog)
    ctx = sim.boot(None)
    return ctx.dev, ctx.fs, ctx.mount, ctx.view


def test_journal_overflow_is_enospc_completion_not_exception():
    """The escape-hatch bugfix: an op that overflows a (tiny) journal used
    to raise a raw JournalFull out of submit_batch; it must complete with
    a per-entry ENOSPC, not poison its neighbours, and stage NOTHING — a
    later commit must never install the torn (sub-)op."""
    dev, fs, m, v = _tiny_journal_mount(nlog=8)  # capacity 7 < one sub-op
    ino = v.create("/f").ino
    fs.journal.commit()
    size0 = v.stat("/f").size
    comps = m.submit([
        SubmissionEntry("write", (ino, 0, b"X" * (12 * 4096)),
                        user_data="too-big"),
        SubmissionEntry("getattr", (ino,), user_data="neighbour"),
    ])
    assert comps[0].errno == Errno.ENOSPC
    assert comps[1].ok
    v.fsync("/f")  # force a commit: the failed sub-op must not surface
    assert v.stat("/f").size == size0
    assert b"X" not in v.read_file("/f")


def test_journal_overflow_scalar_raises_fs_error():
    """Scalar dispatch keeps raising — but as FsError(ENOSPC), the scalar
    API's error surface, never a bare exception type — and the failing
    sub-op's staging rolls back (durable state shows only the committed
    earlier sub-ops, never a torn tail)."""
    from repro.fs.journal import JournalFull

    dev, fs, m, v = _tiny_journal_mount(nlog=8)
    ino = v.create("/f").ino
    fs.journal.commit()
    with pytest.raises(FsError) as ei:
        m.call("write", ino, 0, b"X" * (12 * 4096))
    assert ei.value.errno == Errno.ENOSPC
    assert issubclass(JournalFull, FsError)
    # the failing sub-op staged nothing: size reflects only whole
    # committed sub-ops, and a cold remount agrees with the live view
    v.fsync("/f")
    live = v.read_file("/f")
    assert v.stat("/f").size == len(live)
    from repro.core.services import kernel_binding
    ks2 = kernel_binding(dev, writeback="delayed")
    fs2 = Xv6FileSystem(Xv6Options())
    fs2.init(ks2.superblock(), ks2)
    from repro.fs.mounts import DirectMount
    from repro.fs.posix import PosixView
    assert PosixView(DirectMount(fs2)).read_file("/f") == live


@pytest.mark.parametrize("off", [0, 100])  # 100: partial-block RMW path
@pytest.mark.parametrize("fs_cls_name", ["xv6", "ext4like"])
def test_underestimated_prevresult_chain_member_rolls_back(fs_cls_name, off):
    """A PrevResult-fed write's size is unknowable at reservation time
    (estimated at MAXOP_BLOCKS), so a copy chain read(40 blocks) →
    write(PrevResult) slips past begin_chain and overflows mid-member.
    The member must complete ENOSPC having staged NOTHING — no torn write
    may ever become durable through a later group commit."""
    from repro.fs.ext4like import Ext4LikeFileSystem

    fs_cls = Xv6FileSystem if fs_cls_name == "xv6" else Ext4LikeFileSystem
    dev, fs, m, v = _tiny_journal_mount(nlog=32, n_blocks=4096,
                                        fs_cls=fs_cls)  # capacity 31
    v.write_file("/src", b"S" * (40 * 4096))
    v.fsync("/src")
    v.create("/dst")
    v.fsync("/dst")
    src, dst = v.stat("/src").ino, v.stat("/dst").ino
    pend0 = dict(fs.journal._pending)
    comps = m.submit([
        SubmissionEntry("read", (src, 0, 40 * 4096), user_data="r",
                        flags=SQE_LINK),
        SubmissionEntry("write", (dst, off, PrevResult()), user_data="w",
                        flags=SQE_LINK),
        SubmissionEntry("fsync", (dst,), user_data="s"),
    ])
    by = {c.user_data: c for c in comps}
    assert by["r"].ok and len(by["r"].result) == 40 * 4096
    assert by["w"].errno == Errno.ENOSPC      # overflow, isolated
    assert by["s"].errno == Errno.ECANCELED
    assert dict(fs.journal._pending) == pend0  # member rolled back fully
    v.fsync("/dst")                            # force a commit
    assert v.stat("/dst").size == 0            # nothing torn went durable
    assert v.read_file("/dst") == b""
    assert v.read_file("/src") == b"S" * (40 * 4096)
    v.statfs()


def test_concurrent_unchained_submit_cannot_clobber_chain_member_undo():
    """The gate admits concurrent readers, so an unchained submit can race
    an in-flight chain. Its pre-lock ``in_chain`` peek must be
    thread-owned: the racer takes the plain path (and blocks on the fs
    lock) instead of resetting the chain owner's member undo log — else a
    torn ENOSPC member's staging would survive rollback and go durable."""
    mf = make_mount("bento", n_blocks=4096)
    v = mf.view
    v.write_file("/src", b"S" * (70 * 4096))   # > journal capacity (63)
    v.fsync("/src")
    v.create("/dst")
    v.fsync("/dst")
    src, dst = v.stat("/src").ino, v.stat("/dst").ino
    fs = mf.mount.module
    in_member = threading.Event()
    racer_done = threading.Event()
    orig_log = fs.journal.log_write

    def pausing_log(blockno, data):
        # pause ONCE, mid-staging of the chain's write member (undo log
        # already holds ~20 blocks): the racer interleaves here — it
        # reaches its in_chain peek, then blocks on the fs lock until the
        # chain ends, so the wait always times out; that window is the
        # point
        orig_log(blockno, data)
        if not in_member.is_set() and fs.journal.in_chain \
                and len(fs.journal._pending) >= 20:
            in_member.set()
            racer_done.wait(0.5)

    fs.journal.log_write = pausing_log

    def racer():
        in_member.wait(5)
        # unchained write on another thread while the chain is mid-member
        comps = mf.mount.submit([SubmissionEntry(
            "write", (src, 0, b"r" * 100), user_data="race")])
        assert comps[0].ok
        racer_done.set()

    t = threading.Thread(target=racer, daemon=True)
    t.start()
    from repro.core.interface import PrevResult as PR
    comps = mf.mount.submit([
        SubmissionEntry("read", (src, 0, 70 * 4096), user_data="r",
                        flags=SQE_LINK),
        SubmissionEntry("write", (dst, 0, PR()), user_data="w",
                        flags=SQE_LINK),     # overflows: est misses
        SubmissionEntry("fsync", (dst,), user_data="s"),
    ])
    t.join(5)
    assert not t.is_alive()
    fs.journal.log_write = orig_log
    by = {c.user_data: c for c in comps}
    assert by["w"].errno == Errno.ENOSPC
    v.fsync("/dst")
    assert v.stat("/dst").size == 0   # rollback held despite the race
    mf.close()


def test_chain_scope_taken_per_chain_and_commits_once(mounted):
    """Every SQE_LINK chain submits under one journal chain reservation;
    an in-chain fsync tail commits the whole chain exactly once."""
    if mounted.kind == "fuse":
        pytest.skip("journal lives daemon-side")
    fs = mounted.mount.module
    j = fs.journal
    ch0, c0 = j.chains, j.commits
    comps = mounted.mount.submit([
        SubmissionEntry("create", (1, "chf"), user_data="c",
                        flags=SQE_LINK),
        SubmissionEntry("write", (PrevResult("ino"), 0, b"x" * 5000),
                        user_data="w", flags=SQE_LINK),
        SubmissionEntry("fsync", (PrevResult("ino", back=2),),
                        user_data="s"),
    ])
    assert all(c.ok for c in comps)
    assert j.chains == ch0 + 1
    assert j.commits == c0 + 1          # deferred commit ran at end_chain
    assert not j.in_chain and not j._pending


# --- batched metadata path: service-counter acceptance --------------------------


def test_batched_create_unlink_one_crossing_one_launch():
    """The PR's acceptance counters: a posix-level create_many/unlink_many
    batch crosses the OpGate ONCE (no silent scalar fallback), and a
    flushed batch costs ONE checksum_batch launch (one journal commit)."""
    mf = make_mount("bento", n_blocks=8192)
    v = mf.view
    v.makedirs("/d")                       # warms the dcache for /d
    gate, ks = mf.mount.gate, mf.services
    paths = [f"/d/f{i:03d}" for i in range(64)]

    g0 = gate.crossings
    v.create_many(paths)
    assert gate.crossings - g0 == 1        # one submission, one crossing
    c0 = ks.counters["checksum_batch_calls"]
    v.fsync("/d")
    assert ks.counters["checksum_batch_calls"] - c0 == 1

    g0 = gate.crossings
    v.unlink_many(paths)
    assert gate.crossings - g0 == 1
    c0 = ks.counters["checksum_batch_calls"]
    v.fsync("/d")
    assert ks.counters["checksum_batch_calls"] - c0 == 1
    mf.close()


def test_chained_create_write_fsync_one_crossing_one_launch():
    mf = make_mount("bento", n_blocks=8192)
    v = mf.view
    v.makedirs("/k")
    gate, ks = mf.mount.gate, mf.services
    items = [(f"/k/f{i:03d}", b"d" * 512) for i in range(16)]
    g0 = gate.crossings
    c0 = ks.counters["checksum_batch_calls"]
    out = v.create_and_write_many(items, fsync=True)
    assert out == [512] * 16
    assert gate.crossings - g0 == 1        # 2N+1 entries, one crossing
    assert ks.counters["checksum_batch_calls"] - c0 == 1  # one commit
    mf.close()


def test_create_many_counts_ops_per_entry():
    """stats['ops'] keeps meaning entries, like the other *_many paths."""
    for kind in ("bento", "ext4like"):
        mf = make_mount(kind, n_blocks=4096)
        v = mf.view
        v.makedirs("/d")
        fs = mf.mount.module
        ops0 = fs.stats["ops"]
        v.create_many([f"/d/x{i}" for i in range(5)])
        assert fs.stats["ops"] - ops0 == 5
        ops0 = fs.stats["ops"]
        v.unlink_many([f"/d/x{i}" for i in range(5)])
        assert fs.stats["ops"] - ops0 == 5
        mf.close()


def test_batched_walk_one_lookup_submission_per_level():
    """A cold batched walk of N paths under one parent costs ONE lookup
    submission per tree level — not one per path component."""
    mf = make_mount("bento", n_blocks=4096)
    v = mf.view
    v.makedirs("/a/b")
    for i in range(8):
        v.write_file(f"/a/b/f{i}", b"z")
    v2 = type(v)(mf.mount)                 # fresh view: cold dcache
    gate = mf.mount.gate
    g0 = gate.crossings
    got = v2.stat_many([f"/a/b/f{i}" for i in range(8)])
    assert all(a.size == 1 for a in got)
    # 3 levels of lookups (a, b, f*) + 1 getattr submission
    assert gate.crossings - g0 == 4
    mf.close()


# --- OpGate reentrancy (satellite: nested dispatch during quiesce) --------------


def test_opgate_reentrant_enter_does_not_deadlock_against_freeze():
    gate = OpGate()
    inner_done = threading.Event()
    outer_entered = threading.Event()
    proceed = threading.Event()

    def op():
        gate.enter()
        outer_entered.set()
        proceed.wait(5)
        gate.enter()   # nested (same thread) — must not block on freeze
        gate.exit()
        inner_done.set()
        gate.exit()

    t = threading.Thread(target=op, daemon=True)
    t.start()
    outer_entered.wait(5)
    frozen = threading.Event()

    def freezer():
        gate.freeze()
        frozen.set()

    f = threading.Thread(target=freezer, daemon=True)
    f.start()
    time.sleep(0.05)          # freezer is now waiting on the in-flight op
    proceed.set()
    assert inner_done.wait(5), "nested enter deadlocked against freeze"
    assert frozen.wait(5)
    gate.thaw()
    t.join(5)
    f.join(5)


def test_nested_mount_call_during_concurrent_upgrade():
    """An fs op that re-enters Mount.call on the same thread must survive a
    concurrent upgrade trying to quiesce."""
    mf = make_mount("bento", n_blocks=4096)
    v = mf.view
    v.write_file("/f", b"seed")
    ino = v.stat("/f").ino
    m = mf.mount
    results = []

    def nested_op():
        def inner():
            return m.call("read", ino, 0, 4)
        m.gate.enter()
        try:
            time.sleep(0.1)  # let the upgrade start freezing
            results.append(inner())
        finally:
            m.gate.exit()

    t = threading.Thread(target=nested_op, daemon=True)
    t.start()
    time.sleep(0.02)
    upgrade(m, Xv6FileSystem(Xv6Options()))
    t.join(5)
    assert not t.is_alive()
    assert results == [b"seed"]
    mf.close()


# --- upgrade-during-inflight-batch (§4.8 swap guarantee, batched) ---------------


def test_upgrade_during_inflight_batch_no_lost_or_duplicated_completions():
    mf = make_mount("bento", n_blocks=8192)
    v = mf.view
    v.write_file("/f", b"d" * (128 * 4096))
    v.fsync("/f")
    ino = v.stat("/f").ino
    m = mf.mount
    gen0 = m.generation
    n = 512
    comps = []
    started = threading.Event()

    def submitter():
        entries = [SubmissionEntry("read", (ino, (i % 128) * 4096, 4096),
                                   user_data=i) for i in range(n)]
        started.set()
        comps.extend(m.submit(entries))

    t = threading.Thread(target=submitter, daemon=True)
    t.start()
    started.wait(5)
    stats = upgrade(m, Xv6FileSystem(Xv6Options()))
    t.join(10)
    assert not t.is_alive()
    # exactly one table swap; the batch drained atomically around it
    assert m.generation == gen0 + 1
    assert stats["total_s"] < 10
    # no lost, no duplicated completions; order preserved
    assert [c.user_data for c in comps] == list(range(n))
    assert all(c.ok for c in comps)
    # mount still serves post-upgrade, batched and scalar
    assert v.read_file("/f", 0, 4) == b"dddd"
    assert m.submit([SubmissionEntry("statfs", ())])[0].ok
    mf.close()


# --- BentoQueue wrapper ---------------------------------------------------------


def test_bento_queue_auto_submit_and_drain():
    mf = make_mount("bento", n_blocks=4096)
    v = mf.view
    v.write_file("/f", b"q" * 4096)
    ino = v.stat("/f").ino
    q = BentoQueue(mf.mount, depth=4)
    for i in range(10):
        q.prep("read", ino, i, 1, user_data=i)
    assert len(q) == 2          # 8 auto-submitted in two full batches
    q.submit()
    comps = q.drain()
    assert [c.user_data for c in comps] == list(range(10))
    assert all(isinstance(c, CompletionEntry) and c.result == b"q"
               for c in comps)
    assert q.drain() == []
    mf.close()


# --- transfer_state strict schema (satellite) -----------------------------------


def test_transfer_state_enforces_schema():
    class ModA:
        NAME, VERSION = "a", 1

        def extract_state(self):
            return {"w": 1}

        def state_schema(self):
            return ("w",)

        def restore_state(self, state, from_version):
            self.got = state

    class ModB(ModA):
        VERSION = 2

        def state_schema(self):
            return ("w", "momentum")  # v1 never emitted "momentum"

    with pytest.raises(UpgradeError):
        transfer_state(ModA(), ModB())
    # non-strict keeps the old permissive behaviour
    b = ModB()
    transfer_state(ModA(), b, strict_schema=False)
    assert b.got == {"w": 1}
    # migrate hook can fill the gap — then strict passes
    b2 = ModB()
    transfer_state(ModA(), b2,
                   migrate=lambda s, o, n: {**s, "momentum": 0})
    assert b2.got == {"w": 1, "momentum": 0}


# --- linked timeouts (IOSQE_IO_LINK_TIMEOUT analogue) ----------------------------
#
# A SQE_LINK_TIMEOUT entry guards its chain with a monotonic deadline:
# expired before the drain -> the guard completes ETIME and every other
# member ECANCELED with NOTHING staged; expiring mid-chain cancels the
# remaining members; a chain that beats its deadline completes the guard
# with result 0.  (repro.core.interface.SQE_LINK_TIMEOUT)


def _lt(deadline, **kw):
    from repro.core.interface import SQE_LINK_TIMEOUT

    return SubmissionEntry("link_timeout", (deadline,),
                           flags=SQE_LINK_TIMEOUT | SQE_LINK, **kw)


def test_link_timeout_chain_beats_far_deadline(mounted):
    comps = mounted.mount.submit([
        SubmissionEntry("create", (1, "lt1"), user_data="c",
                        flags=SQE_LINK),
        _lt(time.monotonic() + 60.0, user_data="t"),
        # guards are invisible to the data flow: the default back=1
        # reaches straight through the timer to create's completion
        SubmissionEntry("write", (PrevResult("ino"), 0, b"hi"),
                        user_data="w"),
    ])
    assert [(c.user_data, c.errno) for c in comps] == \
        [("c", None), ("t", None), ("w", None)]
    assert comps[1].result == 0  # the guard's "timer cancelled" completion
    assert mounted.view.read_file("/lt1") == b"hi"


def test_link_timeout_expired_at_drain_stages_nothing(mounted):
    """Deadline already past when the chain drains: ETIME on the guard,
    ECANCELED on every member, and the namespace untouched — the chain
    never reached the fs."""
    comps = mounted.mount.submit([
        SubmissionEntry("create", (1, "never"), user_data="c",
                        flags=SQE_LINK),
        _lt(time.monotonic() - 0.001, user_data="t"),
        SubmissionEntry("write", (PrevResult("ino"), 0, b"x"),
                        user_data="w"),
    ])
    assert [(c.user_data, c.errno) for c in comps] == \
        [("c", Errno.ECANCELED), ("t", Errno.ETIME),
         ("w", Errno.ECANCELED)]
    assert not mounted.view.exists("/never")


def test_link_timeout_expiring_mid_chain_cancels_remainder(
        mounted, monkeypatch):
    """The deadline passes while the chain is executing: members already
    run keep their completions, the guard answers ETIME, the rest are
    ECANCELED. Driven by a fake monotonic clock (real op timings are
    microseconds — far too noisy to race a deadline against)."""
    from repro.core.interface import SQE_LINK_TIMEOUT, Errno as E

    # the executor reads the clock: once at the drain check, then once
    # per entry until expiry. Tick the 4th read past the deadline — the
    # guard's own read — so expiry lands exactly between w1 and w2.
    reads = iter([0.0, 0.0, 0.0, 100.0])
    monkeypatch.setattr(time, "monotonic", lambda: next(reads, 100.0))
    comps = mounted.mount.submit([
        SubmissionEntry("create", (1, "mid"), user_data="c",
                        flags=SQE_LINK),
        SubmissionEntry("write", (PrevResult("ino"), 0, b"payload"),
                        user_data="w1", flags=SQE_LINK),
        SubmissionEntry("link_timeout", (50.0,), user_data="t",
                        flags=SQE_LINK_TIMEOUT | SQE_LINK),
        # back=2 skips w1 (guards don't count) to reach create's ino
        SubmissionEntry("write", (PrevResult("ino", back=2), 7, b"tail"),
                        user_data="w2"),
    ])
    assert [(c.user_data, c.errno) for c in comps] == \
        [("c", None), ("w1", None), ("t", E.ETIME),
         ("w2", E.ECANCELED)]
    # the members that ran before expiry are durable; the canceled tail
    # never landed
    assert mounted.view.read_file("/mid") == b"payload"


def test_link_timeout_malformed_deadline_is_einval(mounted):
    from repro.core.interface import SQE_LINK_TIMEOUT

    comps = mounted.mount.submit([
        SubmissionEntry("create", (1, "bad-dl"), user_data="c",
                        flags=SQE_LINK),
        SubmissionEntry("link_timeout", ("soon",), user_data="t",
                        flags=SQE_LINK_TIMEOUT | SQE_LINK),
        SubmissionEntry("getattr", (1,), user_data="g"),
    ])
    by = {c.user_data: c for c in comps}
    assert by["t"].errno == Errno.EINVAL
    assert by["g"].errno == Errno.ECANCELED  # guard failure cancels on


def test_link_timeout_after_failed_member_is_canceled(mounted):
    """A guard behind an already-failed link is ECANCELED like any other
    member — it never reports ETIME for a chain that died on its own."""
    mounted.view.write_file("/dup", b"")
    comps = mounted.mount.submit([
        SubmissionEntry("create", (1, "dup"), user_data="c",
                        flags=SQE_LINK),                     # EEXIST
        _lt(time.monotonic() + 60.0, user_data="t"),
        SubmissionEntry("getattr", (1,), user_data="g"),
    ])
    assert [(c.user_data, c.errno) for c in comps] == \
        [("c", Errno.EEXIST), ("t", Errno.ECANCELED),
         ("g", Errno.ECANCELED)]


def test_link_timeout_flag_outside_chain_is_einval(mounted):
    """A bare flagged entry with no chain reaches the dispatch table,
    where "link_timeout" is not a filesystem op: EINVAL."""
    from repro.core.interface import SQE_LINK_TIMEOUT

    comps = mounted.mount.submit([
        SubmissionEntry("link_timeout", (time.monotonic() + 60.0,),
                        user_data="t", flags=SQE_LINK_TIMEOUT),
    ])
    assert comps[0].errno == Errno.EINVAL
