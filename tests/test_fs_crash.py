"""Crash-recovery tests: for workloads with a crash injected at an
arbitrary device-write count, journal recovery must yield a consistent
file system in which every fsync'd file is intact — recovered content must
be the fsync'd version or a *later committed* version (group commit may
durably commit subsequent writes on its own). Chained submissions add a
stronger unit: a chain is ONE journal transaction (chain-aware
reservation), so it is crash-atomic at every device-write point.

Crash injection, remount-cold recovery and crash-point enumeration all
live in the shared harness (``repro.fs.crashsim``) — this file carries
the randomized-workload property (hypothesis, when available) and the
deterministic journal unit tests; the exhaustive sweeps are in
``tests/test_crash_torture.py``.
"""

import pytest

try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:  # deterministic tests still run
    hp = None
    st = None

from repro.core.interface import Errno
from repro.core.services import kernel_binding
from repro.fs.blockdev import MemBlockDevice
from repro.fs.crashsim import CrashSim, all_or_nothing, chain_workload
from repro.fs.posix import PosixView
from repro.fs.xv6 import Xv6FileSystem, Xv6Options, mkfs
from repro.fs.mounts import DirectMount


def _fresh_fs(dev=None, n_blocks=2048):
    dev = dev or MemBlockDevice(n_blocks)
    ks = kernel_binding(dev, writeback="delayed")
    if dev.writes == 0:
        mkfs(ks, ninodes=256, nlog=32)
    fs = Xv6FileSystem(Xv6Options(group_commit=True, batched_install=True))
    fs.init(ks.superblock(), ks)
    return dev, ks, fs, PosixView(DirectMount(fs))


def _sim() -> CrashSim:
    return CrashSim(
        lambda: Xv6FileSystem(Xv6Options(group_commit=True,
                                         batched_install=True)))


if hp is not None:
    ops_strategy = st.lists(
        st.tuples(
            st.sampled_from(["write", "append", "fsync_file", "delete"]),
            st.integers(0, 5),          # file index
            st.integers(1, 3),          # payload blocks
        ),
        min_size=1, max_size=25,
    )

    @hp.given(ops=ops_strategy, crash_after=st.integers(1, 400),
              data_seed=st.integers(0, 2**16))
    @hp.settings(max_examples=30, deadline=None)
    def test_crash_recovery_preserves_fsynced_data(ops, crash_after,
                                                   data_seed):
        _crash_recovery_body(ops, crash_after, data_seed)


def _crash_recovery_body(ops, crash_after, data_seed):
    """One randomized workload at one crash point, on the shared harness:
    the workload mutates the model dicts as it goes; the asserts read them
    against the recovered view."""
    history = {}   # path -> list of every version ever written
    floor = {}     # path -> index into history guaranteed durable (fsync)

    def payload(i, blocks):
        return bytes([(data_seed + i) % 251]) * (blocks * 4096)

    def workload(ctx):
        v = ctx.view
        for i, (op, fidx, blocks) in enumerate(ops):
            path = f"/f{fidx}"
            if op == "write":
                data = payload(i, blocks)
                v.write_file(path, data)
                hist = history.setdefault(path, [])
                # write_file overwrites from offset 0; tail of a longer
                # older version survives -> compute effective content
                prev = hist[-1] if hist else b""
                hist.append(data + prev[len(data):])
            elif op == "append":
                data = payload(i, blocks)
                hist = history.setdefault(path, [b""])
                v.append(path, data)
                hist.append(hist[-1] + data)
            elif op == "fsync_file":
                if path in history:
                    v.fsync(path)
                    floor[path] = len(history[path]) - 1
            elif op == "delete":
                if path in history and v.exists(path):
                    v.unlink(path)
                    history.pop(path)
                    floor.pop(path, None)
        # reached only when no crash fired inside the loop: disarm the
        # injector (like the original hand-rolled test — power stays on)
        # and drain to disk, so EVERY surviving version must be durable
        ctx.dev.fail_after_writes = -1
        ctx.fs.flush()
        for p in history:
            floor[p] = len(history[p]) - 1

    rec = _sim().run_one(workload, crash_after)
    v2 = rec.view
    for path, fl in floor.items():
        if path not in history:
            continue  # deleted later; no durability claim on deletes
        assert v2.exists(path), f"{path} was fsync'd but lost after crash"
        got = v2.read_file(path)
        acceptable = history[path][fl:]
        assert any(got == h for h in acceptable), (
            f"{path}: recovered {len(got)}B matches no committed version at "
            f"or after the fsync point")
    # general consistency
    v2.statfs()
    v2.listdir("/")


def test_torn_journal_commit_discarded():
    """Corrupt one journal data block after a staged commit record: recovery
    must detect the checksum mismatch and discard (no partial replay)."""
    import struct
    from repro.fs.journal import _HDR_MAGIC, _HDR_FMT_HEAD

    dev, ks, fs, v = _fresh_fs()
    v.write_file("/a", b"A" * 4096)
    fs.journal.commit()
    geo = fs.geo
    bogus = b"\x42" * 4096
    hdr = struct.pack(_HDR_FMT_HEAD, _HDR_MAGIC, 1, 99)
    hdr += struct.pack("<II", geo.datastart + 5, ks.checksum(bogus))
    dev.write_block(geo.logstart, hdr + b"\0" * (4096 - len(hdr)))
    dev.write_block(geo.logstart + 1, b"TORN" * 1024)  # checksum mismatch
    fs2 = Xv6FileSystem(Xv6Options())
    ks2 = kernel_binding(dev)
    fs2.init(ks2.superblock(), ks2)
    assert fs2.journal.recover() == 0  # discarded, no replay


def test_journal_absorption():
    dev, ks, fs, v = _fresh_fs()
    ino = v.create("/f").ino
    for _ in range(10):
        fs.write(ino, 0, b"same block" * 10)
    assert len(fs.journal._pending) < 8
    fs.journal.commit()
    assert fs.journal.pending_get(0) is None


def test_commit_refused_mid_chain_and_run_by_end_chain():
    """The reservation contract at the journal level: commits requested
    while a chain scope is open defer to end_chain — the chain's blocks
    become durable in ONE transaction, never two."""
    dev, ks, fs, v = _fresh_fs()
    j = fs.journal
    c0 = j.commits
    j.begin_chain(8)
    assert j.in_chain
    j.log_write(fs.geo.datastart + 1, b"a" * 4096)
    j.commit()                       # refused: deferred, nothing written
    assert j.commits == c0 and j._pending
    j.log_write(fs.geo.datastart + 2, b"b" * 4096)
    j.end_chain()                    # deferred commit runs here, once
    assert j.commits == c0 + 1 and not j._pending and not j.in_chain


def test_crash_mid_chain_never_half_applied():
    """The PR 2 hand-rolled sweep, ported onto the shared harness: a
    chained create→write(PrevResult)→fsync crashed at EVERY device-write
    point recovers all-or-nothing (the chain now holds as one journal
    transaction by construction, not by luck of group-commit sizing)."""
    payload = b"C" * (2 * 4096 + 17)  # multi-block: a torn chain would show
    points = _sim().sweep(chain_workload(payload), all_or_nothing(payload))
    assert points > 4  # create+write+commit really hit the device


# --- torn writes vs verified reads (the BlockStore integrity tripwire) -----------
#
# Dedup mounts hash every flushed data block; bulk reads re-hash what the
# cache fetched and surface mismatches as EIO. These sweeps tear ONE
# tracked device block at a time behind the cache's back and assert the
# detector is exact: EIO for precisely the reads that touch the torn
# block, byte-identical data everywhere else, and clean reads again once
# the block's true content is restored.


def _torn_corpus(kind):
    """A small dup-heavy corpus on a fresh dedup mount: 6 files x 4
    blocks from a 6-block pool (shared AND unique blocks end up tracked).
    Returns (mf, files, block_files) where block_files maps device block
    -> set of paths referencing it."""
    from repro.fs.mounts import make_mount

    mf = make_mount(kind, n_blocks=4096)
    v, fs = mf.view, mf.mount.module
    pool = [bytes([17 * (i + 1) % 251]) * 4096 for i in range(6)]
    files = {f"/t{i}": pool[i % 6] + pool[(i + 1) % 6] + pool[0] + pool[i % 3]
             for i in range(6)}
    v.write_many([(p, 0, d) for p, d in files.items()], create=True,
                 fsync=True)
    block_files = {}
    for p in files:
        di = fs._iget(v._walk(p))
        cache = {}
        for bn in range((di.size + 4095) // 4096):
            block_files.setdefault(fs._bmap_ro(di, bn, cache), set()).add(p)
    return mf, files, block_files


def _tear(mf, b, payload=b"torn-behind-the-cache!"):
    """Corrupt device block b under the cache and drop the cached copy;
    returns the original bytes for later restore."""
    orig = bytes(mf.dev.read_block(b))
    raw = bytearray(orig)
    raw[:len(payload)] = payload
    mf.dev.write_block(b, bytes(raw))
    fs = mf.mount.module
    mf.services.sb_invalidate_blocks(fs.sb_cap, [b])
    return orig


@pytest.mark.parametrize("kind", ["dedup-bento", "dedup-ext4like"])
def test_torn_block_sweep_verified_read_many_exact(kind):
    """Sweep EVERY tracked block: tear it, bulk-read the corpus with
    strict=False — EIO lands on exactly the files that reference the torn
    block (shared blocks poison every sharer), clean files stay
    byte-identical, the corruption counter ticks, and restoring the true
    bytes makes the whole corpus read clean again."""
    from repro.core.interface import FsError

    mf, files, block_files = _torn_corpus(kind)
    try:
        v, fs = mf.view, mf.mount.module
        store = fs._blockstore
        tracked = sorted(store.hashval)
        assert len(tracked) >= 4  # the corpus really left hashed blocks
        paths = sorted(files)
        for b in tracked:
            expect_bad = block_files.get(b, set())
            assert expect_bad, f"tracked block {b} not referenced by corpus"
            c0 = v.statfs()["dedup_corruptions_detected"]
            orig = _tear(mf, b)
            got = v.read_many(paths, strict=False)
            bad = {p for p, r in zip(paths, got) if isinstance(r, FsError)}
            assert bad == expect_bad, \
                f"block {b}: EIO on {bad}, expected {expect_bad}"
            for p, r in zip(paths, got):
                if p in expect_bad:
                    assert r.errno == Errno.EIO
                else:
                    assert r == files[p], f"{p} dirtied by unrelated tear"
            assert v.statfs()["dedup_corruptions_detected"] > c0
            # restore the true content: verification must pass again
            mf.dev.write_block(b, orig)
            mf.services.sb_invalidate_blocks(fs.sb_cap, [b])
            clean = v.read_many(paths, strict=False)
            assert [r for r in clean if isinstance(r, FsError)] == []
            assert all(r == files[p] for p, r in zip(paths, clean))
    finally:
        mf.close()


@pytest.mark.parametrize("kind", ["dedup-bento", "dedup-ext4like"])
def test_torn_block_slice_reads_are_block_precise(kind):
    """Detection is per fetched block, not per file: a ranged read_many
    slice that avoids the torn block succeeds even inside a file whose
    OTHER blocks are torn, while any slice overlapping it gets EIO."""
    from repro.core.interface import FsError

    mf, files, block_files = _torn_corpus(kind)
    try:
        v, fs = mf.view, mf.mount.module
        # pick a block referenced mid-file so both sides exist
        victim_path, victim_bn = None, None
        for p in sorted(files):
            di = fs._iget(v._walk(p))
            b1 = fs._bmap_ro(di, 1, {})
            if b1 in fs._blockstore.hashval:
                victim_path, victim_bn, victim_b = p, 1, b1
                break
        assert victim_path is not None
        _tear(mf, victim_b)
        specs = [(victim_path, 0, 4096),              # before the tear
                 (victim_path, victim_bn * 4096, 4096),   # the torn block
                 (victim_path, 2 * 4096, 4096)]       # after the tear
        got = v.read_many(specs, strict=False)
        data = files[victim_path]
        sharers = block_files[victim_b]
        assert isinstance(got[1], FsError) and got[1].errno == Errno.EIO
        if victim_b not in (fs._bmap_ro(fs._iget(v._walk(victim_path)), 0, {}),
                            fs._bmap_ro(fs._iget(v._walk(victim_path)), 2, {})):
            assert got[0] == data[:4096]
            assert got[2] == data[2 * 4096:3 * 4096]
        # strict=True raises out of the batch instead of returning slots
        with pytest.raises(FsError):
            v.read_many([(victim_path, victim_bn * 4096, 4096)])
        # every OTHER sharer of the shared torn block is poisoned too
        others = sorted(sharers - {victim_path})
        if others:
            got2 = v.read_many(others, strict=False)
            assert all(isinstance(r, FsError) for r in got2)
    finally:
        mf.close()
