"""Crash-recovery tests: for workloads with a crash injected at an
arbitrary device-write count, journal recovery must yield a consistent
file system in which every fsync'd file is intact — recovered content must
be the fsync'd version or a *later committed* version (group commit may
durably commit subsequent writes on its own). Chained submissions add a
stronger unit: a chain is ONE journal transaction (chain-aware
reservation), so it is crash-atomic at every device-write point.

Crash injection, remount-cold recovery and crash-point enumeration all
live in the shared harness (``repro.fs.crashsim``) — this file carries
the randomized-workload property (hypothesis, when available) and the
deterministic journal unit tests; the exhaustive sweeps are in
``tests/test_crash_torture.py``.
"""

import pytest

try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:  # deterministic tests still run
    hp = None
    st = None

from repro.core.services import kernel_binding
from repro.fs.blockdev import MemBlockDevice
from repro.fs.crashsim import CrashSim, all_or_nothing, chain_workload
from repro.fs.posix import PosixView
from repro.fs.xv6 import Xv6FileSystem, Xv6Options, mkfs
from repro.fs.mounts import DirectMount


def _fresh_fs(dev=None, n_blocks=2048):
    dev = dev or MemBlockDevice(n_blocks)
    ks = kernel_binding(dev, writeback="delayed")
    if dev.writes == 0:
        mkfs(ks, ninodes=256, nlog=32)
    fs = Xv6FileSystem(Xv6Options(group_commit=True, batched_install=True))
    fs.init(ks.superblock(), ks)
    return dev, ks, fs, PosixView(DirectMount(fs))


def _sim() -> CrashSim:
    return CrashSim(
        lambda: Xv6FileSystem(Xv6Options(group_commit=True,
                                         batched_install=True)))


if hp is not None:
    ops_strategy = st.lists(
        st.tuples(
            st.sampled_from(["write", "append", "fsync_file", "delete"]),
            st.integers(0, 5),          # file index
            st.integers(1, 3),          # payload blocks
        ),
        min_size=1, max_size=25,
    )

    @hp.given(ops=ops_strategy, crash_after=st.integers(1, 400),
              data_seed=st.integers(0, 2**16))
    @hp.settings(max_examples=30, deadline=None)
    def test_crash_recovery_preserves_fsynced_data(ops, crash_after,
                                                   data_seed):
        _crash_recovery_body(ops, crash_after, data_seed)


def _crash_recovery_body(ops, crash_after, data_seed):
    """One randomized workload at one crash point, on the shared harness:
    the workload mutates the model dicts as it goes; the asserts read them
    against the recovered view."""
    history = {}   # path -> list of every version ever written
    floor = {}     # path -> index into history guaranteed durable (fsync)

    def payload(i, blocks):
        return bytes([(data_seed + i) % 251]) * (blocks * 4096)

    def workload(ctx):
        v = ctx.view
        for i, (op, fidx, blocks) in enumerate(ops):
            path = f"/f{fidx}"
            if op == "write":
                data = payload(i, blocks)
                v.write_file(path, data)
                hist = history.setdefault(path, [])
                # write_file overwrites from offset 0; tail of a longer
                # older version survives -> compute effective content
                prev = hist[-1] if hist else b""
                hist.append(data + prev[len(data):])
            elif op == "append":
                data = payload(i, blocks)
                hist = history.setdefault(path, [b""])
                v.append(path, data)
                hist.append(hist[-1] + data)
            elif op == "fsync_file":
                if path in history:
                    v.fsync(path)
                    floor[path] = len(history[path]) - 1
            elif op == "delete":
                if path in history and v.exists(path):
                    v.unlink(path)
                    history.pop(path)
                    floor.pop(path, None)
        # reached only when no crash fired inside the loop: disarm the
        # injector (like the original hand-rolled test — power stays on)
        # and drain to disk, so EVERY surviving version must be durable
        ctx.dev.fail_after_writes = -1
        ctx.fs.flush()
        for p in history:
            floor[p] = len(history[p]) - 1

    rec = _sim().run_one(workload, crash_after)
    v2 = rec.view
    for path, fl in floor.items():
        if path not in history:
            continue  # deleted later; no durability claim on deletes
        assert v2.exists(path), f"{path} was fsync'd but lost after crash"
        got = v2.read_file(path)
        acceptable = history[path][fl:]
        assert any(got == h for h in acceptable), (
            f"{path}: recovered {len(got)}B matches no committed version at "
            f"or after the fsync point")
    # general consistency
    v2.statfs()
    v2.listdir("/")


def test_torn_journal_commit_discarded():
    """Corrupt one journal data block after a staged commit record: recovery
    must detect the checksum mismatch and discard (no partial replay)."""
    import struct
    from repro.fs.journal import _HDR_MAGIC, _HDR_FMT_HEAD

    dev, ks, fs, v = _fresh_fs()
    v.write_file("/a", b"A" * 4096)
    fs.journal.commit()
    geo = fs.geo
    bogus = b"\x42" * 4096
    hdr = struct.pack(_HDR_FMT_HEAD, _HDR_MAGIC, 1, 99)
    hdr += struct.pack("<II", geo.datastart + 5, ks.checksum(bogus))
    dev.write_block(geo.logstart, hdr + b"\0" * (4096 - len(hdr)))
    dev.write_block(geo.logstart + 1, b"TORN" * 1024)  # checksum mismatch
    fs2 = Xv6FileSystem(Xv6Options())
    ks2 = kernel_binding(dev)
    fs2.init(ks2.superblock(), ks2)
    assert fs2.journal.recover() == 0  # discarded, no replay


def test_journal_absorption():
    dev, ks, fs, v = _fresh_fs()
    ino = v.create("/f").ino
    for _ in range(10):
        fs.write(ino, 0, b"same block" * 10)
    assert len(fs.journal._pending) < 8
    fs.journal.commit()
    assert fs.journal.pending_get(0) is None


def test_commit_refused_mid_chain_and_run_by_end_chain():
    """The reservation contract at the journal level: commits requested
    while a chain scope is open defer to end_chain — the chain's blocks
    become durable in ONE transaction, never two."""
    dev, ks, fs, v = _fresh_fs()
    j = fs.journal
    c0 = j.commits
    j.begin_chain(8)
    assert j.in_chain
    j.log_write(fs.geo.datastart + 1, b"a" * 4096)
    j.commit()                       # refused: deferred, nothing written
    assert j.commits == c0 and j._pending
    j.log_write(fs.geo.datastart + 2, b"b" * 4096)
    j.end_chain()                    # deferred commit runs here, once
    assert j.commits == c0 + 1 and not j._pending and not j.in_chain


def test_crash_mid_chain_never_half_applied():
    """The PR 2 hand-rolled sweep, ported onto the shared harness: a
    chained create→write(PrevResult)→fsync crashed at EVERY device-write
    point recovers all-or-nothing (the chain now holds as one journal
    transaction by construction, not by luck of group-commit sizing)."""
    payload = b"C" * (2 * 4096 + 17)  # multi-block: a torn chain would show
    points = _sim().sweep(chain_workload(payload), all_or_nothing(payload))
    assert points > 4  # create+write+commit really hit the device
