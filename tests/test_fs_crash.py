"""Crash-recovery tests: for workloads with a crash injected at an
arbitrary device-write count, journal recovery must yield a consistent
file system in which every fsync'd file is intact — recovered content must
be the fsync'd version or a *later committed* version (group commit may
durably commit subsequent writes on its own). Chained submissions add a
stronger unit: a chain that fits one journal transaction is crash-atomic
(no half-applied chain survives replay).

The workload-randomizing test is property-based (hypothesis); the
deterministic tests — torn-commit discard, absorption, crash-mid-chain
sweep — run everywhere.
"""

import pytest

try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:  # deterministic tests still run
    hp = None
    st = None

from repro.core.services import kernel_binding
from repro.fs.blockdev import BlockDeviceError, MemBlockDevice
from repro.fs.posix import PosixView
from repro.fs.xv6 import Xv6FileSystem, Xv6Options, mkfs
from repro.fs.mounts import DirectMount


def _fresh_fs(dev=None, n_blocks=2048):
    dev = dev or MemBlockDevice(n_blocks)
    ks = kernel_binding(dev, writeback="delayed")
    if dev.writes == 0:
        mkfs(ks, ninodes=256, nlog=32)
    fs = Xv6FileSystem(Xv6Options(group_commit=True, batched_install=True))
    fs.init(ks.superblock(), ks)
    return dev, ks, fs, PosixView(DirectMount(fs))


if hp is not None:
    ops_strategy = st.lists(
        st.tuples(
            st.sampled_from(["write", "append", "fsync_file", "delete"]),
            st.integers(0, 5),          # file index
            st.integers(1, 3),          # payload blocks
        ),
        min_size=1, max_size=25,
    )

    @hp.given(ops=ops_strategy, crash_after=st.integers(1, 400),
              data_seed=st.integers(0, 2**16))
    @hp.settings(max_examples=30, deadline=None)
    def test_crash_recovery_preserves_fsynced_data(ops, crash_after,
                                                   data_seed):
        _crash_recovery_body(ops, crash_after, data_seed)


def _crash_recovery_body(ops, crash_after, data_seed):
    dev, ks, fs, v = _fresh_fs()
    history = {}   # path -> list of every version ever written
    floor = {}     # path -> index into history guaranteed durable (fsync)
    deleted_after_floor = set()

    def payload(i, blocks):
        return bytes([(data_seed + i) % 251]) * (blocks * 4096)

    dev.fail_after_writes = crash_after
    crashed = False
    try:
        for i, (op, fidx, blocks) in enumerate(ops):
            path = f"/f{fidx}"
            if op == "write":
                data = payload(i, blocks)
                v.write_file(path, data)
                hist = history.setdefault(path, [])
                # our write_file overwrites from offset 0; tail of a longer
                # older version survives -> compute effective content
                prev = hist[-1] if hist else b""
                eff = data + prev[len(data):]
                hist.append(eff)
            elif op == "append":
                data = payload(i, blocks)
                hist = history.setdefault(path, [b""])
                v.append(path, data)
                hist.append(hist[-1] + data)
            elif op == "fsync_file":
                if path in history:
                    v.fsync(path)
                    floor[path] = len(history[path]) - 1
                    deleted_after_floor.discard(path)
            elif op == "delete":
                if path in history and v.exists(path):
                    v.unlink(path)
                    history.pop(path)
                    floor.pop(path, None)
    except BlockDeviceError:
        crashed = True

    # power back on before any post-mortem I/O
    dev.fail_after_writes = -1

    if not crashed:
        fs.flush()
        for p in history:
            floor[p] = len(history[p]) - 1
    ks2 = kernel_binding(dev, writeback="delayed")
    fs2 = Xv6FileSystem(Xv6Options())
    fs2.init(ks2.superblock(), ks2)
    v2 = PosixView(DirectMount(fs2))

    for path, fl in floor.items():
        if path not in history:
            continue  # deleted later; no durability claim on deletes
        assert v2.exists(path), f"{path} was fsync'd but lost after crash"
        got = v2.read_file(path)
        acceptable = history[path][fl:]
        assert any(got == h for h in acceptable), (
            f"{path}: recovered {len(got)}B matches no committed version at "
            f"or after the fsync point")
    # general consistency
    v2.statfs()
    v2.listdir("/")


def test_torn_journal_commit_discarded():
    """Corrupt one journal data block after a staged commit record: recovery
    must detect the checksum mismatch and discard (no partial replay)."""
    import struct
    from repro.fs.journal import _HDR_MAGIC, _HDR_FMT_HEAD

    dev, ks, fs, v = _fresh_fs()
    v.write_file("/a", b"A" * 4096)
    fs.journal.commit()
    geo = fs.geo
    bogus = b"\x42" * 4096
    hdr = struct.pack(_HDR_FMT_HEAD, _HDR_MAGIC, 1, 99)
    hdr += struct.pack("<II", geo.datastart + 5, ks.checksum(bogus))
    dev.write_block(geo.logstart, hdr + b"\0" * (4096 - len(hdr)))
    dev.write_block(geo.logstart + 1, b"TORN" * 1024)  # checksum mismatch
    fs2 = Xv6FileSystem(Xv6Options())
    ks2 = kernel_binding(dev)
    fs2.init(ks2.superblock(), ks2)
    assert fs2.journal.recover() == 0  # discarded, no replay


def test_journal_absorption():
    dev, ks, fs, v = _fresh_fs()
    ino = v.create("/f").ino
    for _ in range(10):
        fs.write(ino, 0, b"same block" * 10)
    assert len(fs.journal._pending) < 8
    fs.journal.commit()
    assert fs.journal.pending_get(0) is None


def test_crash_mid_chain_never_half_applied():
    """Chained create→write→flush with a crash injected at EVERY device-
    write count the chain can reach (including between the create and the
    write, and inside the journal commit): after replay the file either
    does not exist, or exists with the COMPLETE payload — a half-applied
    chain (entry without data, torn tail) must never survive. Holds
    because both chain members land in one group-commit transaction and
    the journal replays transactions atomically (torn commits discarded)."""
    from repro.core.interface import PrevResult, SQE_LINK, SubmissionEntry

    payload = b"C" * (2 * 4096 + 17)  # multi-block: a torn chain would show

    # measure the chain's total device-write footprint first
    dev, ks, fs, v = _fresh_fs()
    entries = [
        SubmissionEntry("create", (1, "f"), user_data="c", flags=SQE_LINK),
        SubmissionEntry("write", (PrevResult("ino"), 0, payload),
                        user_data="w", flags=SQE_LINK),
        SubmissionEntry("flush", (), user_data="s"),
    ]
    base_writes = dev.writes
    comps = v.m.submit(entries)
    assert all(c.ok for c in comps)
    footprint = dev.writes - base_writes
    assert footprint > 4  # create+write+commit really hit the device

    half_applied = []
    for crash_after in range(1, footprint + 1):
        dev, ks, fs, v = _fresh_fs()
        dev._writes_seen = 0          # count from here, mkfs writes excluded
        dev.fail_after_writes = crash_after
        crashed = False
        try:
            v.m.submit([
                SubmissionEntry("create", (1, "f"), user_data="c",
                                flags=SQE_LINK),
                SubmissionEntry("write", (PrevResult("ino"), 0, payload),
                                user_data="w", flags=SQE_LINK),
                SubmissionEntry("flush", (), user_data="s"),
            ])
        except BlockDeviceError:
            crashed = True
        dev.fail_after_writes = -1
        # power back on: fresh module instances over the surviving blocks
        ks2 = kernel_binding(dev, writeback="delayed")
        fs2 = Xv6FileSystem(Xv6Options())
        fs2.init(ks2.superblock(), ks2)
        v2 = PosixView(DirectMount(fs2))
        if v2.exists("/f"):
            got = v2.read_file("/f")
            if got != payload:
                half_applied.append((crash_after, crashed, len(got)))
        v2.statfs()
        v2.listdir("/")
    assert not half_applied, \
        f"half-applied chains survived recovery: {half_applied}"
