"""Data pipeline determinism, FS-backed shards, straggler retry, and the
checkpoint store (through the Bento FS) incl. corruption detection."""

import threading
import time

import jax.numpy as jnp
import numpy as np
import pytest

from repro import checkpoint as ckpt
from repro.configs import registry
from repro.data.pipeline import (FsShardReader, Prefetcher, SyntheticLM,
                                 write_shards)
from repro.fs.mounts import make_mount


def test_synthetic_determinism():
    cfg = registry.get("smollm-135m").smoke
    d1 = SyntheticLM(cfg, 4, 32, seed=7)
    d2 = SyntheticLM(cfg, 4, 32, seed=7)
    for s in (0, 3, 1000):
        np.testing.assert_array_equal(d1.batch(s)["tokens"], d2.batch(s)["tokens"])
    assert not np.array_equal(d1.batch(0)["tokens"], d1.batch(1)["tokens"])


def test_fs_shards_roundtrip():
    cfg = registry.get("smollm-135m").smoke
    mf = make_mount("bento", n_blocks=8192)
    ds = SyntheticLM(cfg, 2, 64, seed=1)
    write_shards(mf.view, ds, n_shards=3)
    rd = FsShardReader(mf.view)
    for i in range(3):
        got = rd.read(i)
        np.testing.assert_array_equal(got["tokens"], ds.batch(i)["tokens"])
    got = rd.read(5)  # wraps around
    np.testing.assert_array_equal(got["tokens"], ds.batch(2)["tokens"])
    mf.close()


def test_straggler_redispatch():
    cfg = registry.get("smollm-135m").smoke
    mf = make_mount("bento", n_blocks=8192)
    write_shards(mf.view, SyntheticLM(cfg, 2, 32), n_shards=2)
    rd = FsShardReader(mf.view, timeout_s=0.2)
    orig = rd.view.read_file
    calls = {"n": 0}

    def slow_once(path, **kw):
        calls["n"] += 1
        if calls["n"] == 1:
            time.sleep(0.6)  # first attempt straggles past the deadline
        return orig(path, **kw)

    rd.view.read_file = slow_once
    got = rd.read(0)
    assert rd.retries >= 1
    assert "tokens" in got
    mf.close()


def test_prefetcher_in_order():
    seen = []
    pf = Prefetcher(lambda s: {"step": s}, start_step=5)
    for want in (5, 6, 7):
        s, item = pf.next()
        assert s == want and item["step"] == want
    pf.close()


# --- checkpoint store -------------------------------------------------------------


def test_checkpoint_roundtrip_with_checksums():
    mf = make_mount("bento", n_blocks=16384)
    cks = mf.services.checksum
    tree = {"w": jnp.arange(12.0).reshape(3, 4), "step": jnp.int32(7),
            "nested": {"b": jnp.ones((5,), jnp.bfloat16)}}
    ckpt.save(mf.view, "/ck/step_1", tree, step=1, checksum=cks)
    like = {"w": jnp.zeros((3, 4)), "step": jnp.int32(0),
            "nested": {"b": jnp.zeros((5,), jnp.bfloat16)}}
    back, mf_ = ckpt.load(mf.view, "/ck/step_1", like, checksum=cks)
    np.testing.assert_array_equal(np.asarray(back["w"]), np.asarray(tree["w"]))
    assert int(back["step"]) == 7
    assert back["nested"]["b"].dtype == jnp.bfloat16
    mf.close()


def test_checkpoint_corruption_detected():
    mf = make_mount("bento", n_blocks=16384)
    cks = mf.services.checksum
    tree = {"w": jnp.ones((64, 64))}
    man = ckpt.save(mf.view, "/ck/s", tree, step=0, checksum=cks)
    path = man["leaves"][0]["shards"][0]["path"]
    raw = bytearray(mf.view.read_file(path))
    raw[500] ^= 0xFF
    mf.view.write_file(path, bytes(raw), off=0, create=False)
    with pytest.raises(IOError):
        ckpt.load(mf.view, "/ck/s", tree, checksum=cks)
    mf.close()


def test_failed_chained_save_leaves_no_manifest():
    """The manifest rides the final leaf batch as a linked chain: if a leaf
    write fails, the manifest write is cancelled AND the pre-created empty
    manifest file is cleaned up, so an aborted save is indistinguishable
    from no save (latest_step must not see it). The raised error is the
    failing member's real errno, not the chain's ECANCELED."""
    from repro.core.interface import Errno, FsError

    mf = make_mount("bento", n_blocks=16384)
    v = mf.view
    v.makedirs("/ck/step_9")
    fs = mf.mount.module
    real_write = type(fs).write
    armed = {"left": 1}

    def sabotaged_write(self, ino, off, data):
        # the first write of the save is the first LEAF's data (leaves
        # land before the manifest chain ever starts)
        if armed["left"]:
            armed["left"] -= 1
            raise FsError(Errno.ENOSPC, "injected leaf failure")
        return real_write(self, ino, off, data)

    type(fs).write = sabotaged_write
    try:
        with pytest.raises(FsError) as exc:
            ckpt.save(mf.view, "/ck/step_9", {"w": jnp.zeros(4)}, step=9)
        assert exc.value.errno == Errno.ENOSPC  # root cause, not ECANCELED
    finally:
        type(fs).write = real_write
    assert not v.exists("/ck/step_9/manifest.json")
    assert ckpt.latest_step(mf.view, "/ck") is None
    # and the aborted save does not poison a subsequent good one
    ckpt.save(mf.view, "/ck/step_9", {"w": jnp.arange(4.0)}, step=9)
    assert ckpt.latest_step(mf.view, "/ck") == 9
    mf.close()


def test_checkpoint_resave_changes_and_shrinks_leaves():
    """Re-saving the same step with DIFFERENT (and smaller) leaf data:
    generation-tagged leaf names mean the new data never overwrites the
    live checkpoint's files (an in-place shorter overwrite would keep the
    old tail and fail the checksum), the swap is atomic, and the previous
    generation's leaves are garbage-collected after it."""
    mf = make_mount("bento", n_blocks=16384)
    cks = mf.services.checksum
    big = {"w": jnp.arange(4096.0)}
    ckpt.save(mf.view, "/ck/step_3", big, step=3, checksum=cks)
    small = {"w": jnp.full((8,), 5.0)}
    man = ckpt.save(mf.view, "/ck/step_3", small, step=3, checksum=cks)
    assert man["gen"] == 1
    back, _ = ckpt.load(mf.view, "/ck/step_3", {"w": jnp.zeros(8)},
                        checksum=cks)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(small["w"]))
    # prior generation's leaves collected; only the live ones remain
    leaves = [n for n in mf.view.listdir("/ck/step_3")
              if n.startswith("leaf_")]
    assert leaves == ["leaf_00000_s000_g1.npy"]
    # a third save keeps rolling generations forward
    man = ckpt.save(mf.view, "/ck/step_3", big, step=3, checksum=cks)
    assert man["gen"] == 2
    back, _ = ckpt.load(mf.view, "/ck/step_3", {"w": jnp.zeros(4096)},
                        checksum=cks)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(big["w"]))
    mf.close()


def test_checkpoint_resave_probes_past_crashed_attempts_leaves():
    """A re-save whose predecessor CRASHED before its manifest swap left
    gen-1 leaves on disk while the live manifest still says gen 0; the
    next re-save must probe PAST those occupied names instead of
    overwriting them in place (write never truncates — a shorter
    overwrite would keep the stale tail and fail the load checksum)."""
    mf = make_mount("bento", n_blocks=16384)
    cks = mf.services.checksum
    ckpt.save(mf.view, "/ck/step_5", {"w": jnp.ones(16)}, step=5,
              checksum=cks)
    # fake the crashed attempt: a gen-1 leaf LONGER than the next save's.
    # Use the v1 (whole-leaf) name — the probe must honor BOTH naming
    # lines, so a crashed pre-upgrade attempt still pushes the gen tag.
    mf.view.write_file("/ck/step_5/leaf_00000_g1.npy", b"G" * 8192)
    man = ckpt.save(mf.view, "/ck/step_5", {"w": jnp.full((4,), 9.0)},
                    step=5, checksum=cks)
    assert man["gen"] == 2                      # probed past the orphan
    back, _ = ckpt.load(mf.view, "/ck/step_5", {"w": jnp.zeros(4)},
                        checksum=cks)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.full((4,), 9.0, np.float32))
    # the orphan and the old generation were both collected after the swap
    leaves = sorted(n for n in mf.view.listdir("/ck/step_5")
                    if n.startswith("leaf_"))
    assert leaves == ["leaf_00000_s000_g2.npy"]
    mf.close()


def test_latest_step():
    mf = make_mount("bento", n_blocks=16384)
    assert ckpt.latest_step(mf.view, "/ck") is None
    for s in (2, 10, 6):
        ckpt.save(mf.view, f"/ck/step_{s:08d}", {"x": jnp.zeros(3)}, step=s)
    assert ckpt.latest_step(mf.view, "/ck") == 10
    mf.close()
