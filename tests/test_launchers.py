"""CLI launcher smoke tests (the public entry points don't rot)."""

import os
import subprocess
import sys

import pytest

SRC = os.path.abspath(os.path.join(os.path.dirname(__file__), "..", "src"))


def _run(args, timeout=420):
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC
    return subprocess.run([sys.executable, "-m"] + args, env=env,
                          capture_output=True, text=True, timeout=timeout)


@pytest.mark.slow
def test_train_cli():
    out = _run(["repro.launch.train", "--arch", "smollm-135m", "--smoke",
                "--steps", "5", "--batch", "2", "--seq", "32"])
    assert out.returncode == 0, out.stderr[-1500:]
    assert "loss" in out.stdout and "tok/s" in out.stdout


@pytest.mark.slow
def test_serve_cli():
    out = _run(["repro.launch.serve", "--arch", "zamba2-7b", "--smoke",
                "--batch", "1", "--prompt-len", "16", "--gen", "4"])
    assert out.returncode == 0, out.stderr[-1500:]
    assert "decode" in out.stdout


@pytest.mark.slow
def test_dryrun_cli_skip_cell():
    """A skipped cell must exit 0 with a SKIP record."""
    out = _run(["repro.launch.dryrun", "--arch", "smollm-135m",
                "--shape", "long_500k", "--mesh", "single",
                "--out", "/tmp/dryrun_skip_test"])
    assert out.returncode == 0, out.stderr[-1500:]
    assert "SKIP" in out.stdout
