"""Cross-path consistency: prefill+decode must reproduce teacher-forced
forward logits for every family (the serving path equals the train path)."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.distributed.sharding import ShardingCtx
from repro.models import lm, params as P

ARCHS = registry.arch_ids()
CTX = ShardingCtx.null()


def _full_logits(cfg, run, prm, batch):
    """Teacher-forced logits at every position via the training backbone."""
    from repro.models.common import logits_fn, rms_norm
    x, _aux = lm._backbone(cfg, run, CTX, prm, batch, batch["tokens"])
    x = rms_norm(x, prm["final_ln"], cfg.norm_eps)
    return logits_fn(prm["embed"], x, CTX)


@pytest.mark.parametrize("arch", ARCHS)
def test_prefill_decode_matches_forward(arch):
    b = registry.get(arch)
    cfg, run = b.smoke, b.run
    rng = jax.random.PRNGKey(0)
    # fp32 compute for a tight numeric comparison
    run = run.replace(compute_dtype="float32")
    prm = P.materialize(lm.param_specs(cfg), rng, dtype="float32")
    B, S_prompt, S_gen = 2, 16, 4
    S = S_prompt + S_gen
    toks = jax.random.randint(jax.random.PRNGKey(1), (B, S), 0, cfg.vocab_size)
    batch = {"tokens": toks}
    if cfg.family == "vlm":
        batch["image_embeds"] = 0.02 * jnp.ones(
            (B, cfg.num_image_tokens, cfg.d_model), jnp.float32)
    if cfg.family == "audio":
        batch["frame_embeds"] = 0.02 * jnp.ones(
            (B, cfg.encoder_seq, cfg.d_model), jnp.float32)

    full = _full_logits(cfg, run, prm, batch)  # (B, S, V)

    # prefill on the prompt, then decode the remaining tokens teacher-forced
    pb = dict(batch)
    pb["tokens"] = toks[:, :S_prompt]
    logits_p, cache = lm.prefill_fn(cfg, run, CTX, prm, pb)
    got = [logits_p]

    # grow transformer caches to S (rwkv/zamba mamba states are fixed-size)
    def pad_seq(x, by):
        padw = [(0, 0)] * x.ndim
        padw[-3] = (0, by)
        return jnp.pad(x, padw)

    if cfg.sliding_window == 0:
        if cfg.family in ("dense", "moe"):
            cache = {"k": pad_seq(cache["k"], S_gen), "v": pad_seq(cache["v"], S_gen)}
        elif cfg.family == "vlm":
            cache = {"self": {"k": pad_seq(cache["self"]["k"], S_gen),
                              "v": pad_seq(cache["self"]["v"], S_gen)},
                     "cross": cache["cross"]}
        elif cfg.family == "audio":
            cache = {"k": pad_seq(cache["k"], S_gen), "v": pad_seq(cache["v"], S_gen),
                     "ck": cache["ck"], "cv": cache["cv"]}
        elif cfg.family == "hybrid" and "attn" in cache:
            cache = {"mamba": cache["mamba"],
                     "attn": {"k": pad_seq(cache["attn"]["k"], S_gen),
                              "v": pad_seq(cache["attn"]["v"], S_gen)}}

    for i in range(S_gen - 1):
        pos = jnp.int32(S_prompt + i)
        db = {"tokens": toks[:, S_prompt + i][:, None], "pos": pos}
        logits_d, cache = lm.decode_fn(cfg, run, CTX, prm, cache, db)
        got.append(logits_d)

    want = jnp.stack([full[:, S_prompt - 1 + i] for i in range(S_gen)], axis=1)
    got = jnp.stack(got, axis=1)
    err = float(jnp.max(jnp.abs(got - want)))
    scale = float(jnp.maximum(jnp.max(jnp.abs(want)), 1.0))
    assert err / scale < 5e-3, f"{arch}: decode/forward logits diverge ({err=})"


@pytest.mark.parametrize("arch", ["h2o-danube-3-4b"])
def test_swa_ring_buffer_decode(arch):
    """SWA decode past the window must keep working (ring buffer wrap)."""
    b = registry.get(arch)
    cfg, run = b.smoke, b.run  # smoke window = 32
    prm = P.materialize(lm.param_specs(cfg), jax.random.PRNGKey(0))
    B = 1
    S_prompt = cfg.sliding_window  # fill the window exactly
    toks = jax.random.randint(jax.random.PRNGKey(2), (B, S_prompt), 0,
                              cfg.vocab_size)
    _, cache = lm.prefill_fn(cfg, run, CTX, prm, {"tokens": toks})
    # decode 8 tokens past the window: wraps the ring
    for i in range(8):
        pos = jnp.int32(S_prompt + i)
        logits, cache = lm.decode_fn(cfg, run, CTX, prm, cache,
                                     {"tokens": jnp.ones((B, 1), jnp.int32),
                                      "pos": pos})
        assert bool(jnp.all(jnp.isfinite(logits)))


def test_chunked_attention_equals_dense():
    from repro.models import attention as A
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (2, 2048, 4, 32))
    k = jax.random.normal(ks[1], (2, 2048, 2, 32))
    v = jax.random.normal(ks[2], (2, 2048, 2, 32))
    dense = A.attention_dense(q, k, v, causal=True)
    chunked = A.attention_chunked(q, k, v, causal=True, q_chunk=512)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(chunked),
                               atol=2e-5)
    # SWA with static kv slicing
    dense_w = A.attention_dense(q, k, v, causal=True, window=512)
    chunk_w = A.attention_chunked(q, k, v, causal=True, window=512, q_chunk=512)
    np.testing.assert_allclose(np.asarray(dense_w), np.asarray(chunk_w),
                               atol=2e-5)


def test_flash_decode_matches_dense_on_mesh():
    """shard_map LSE-combined decode == dense decode (1-device mesh)."""
    from repro.launch.mesh import make_host_mesh
    from repro.models import attention as A
    mesh = make_host_mesh(1, 1)
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    B, S, Hq, Hkv, D = 2, 64, 4, 2, 32
    q = jax.random.normal(ks[0], (B, 1, Hq, D))
    ck = jax.random.normal(ks[1], (B, S, Hkv, D))
    cv = jax.random.normal(ks[2], (B, S, Hkv, D))
    pos = jnp.int32(40)
    dense = A.decode_attention(q, ck, cv, pos)
    flash = A.flash_decode(q, ck, cv, pos, mesh)
    np.testing.assert_allclose(np.asarray(dense), np.asarray(flash), atol=1e-5)
