"""FUSE daemon error-contract regressions.

The pre-fix daemon swallowed every scalar-op exception into a generic
"error" reply and kept serving the channel — a poisoned op (unknown name,
bad argument types, daemon-side state corruption) looked exactly like an
fs refusal, and an undecodable frame propagated OUT of the service loop
and killed every other channel with an unexplained EOF. The contract now:
``FsError`` -> errno to the caller (the fs refusing is normal operation);
anything else is logged with a traceback, surfaced to the caller, and
FAILS that one channel while the daemon and its other channels live on.
"""

import pickle
import socket
import struct
import threading

import pytest

from repro.core.interface import Errno, FsError
from repro.fs.fusebridge import _recv, _send
from repro.fs.mounts import make_mount


@pytest.fixture
def mf():
    m = make_mount("fuse", n_blocks=2048)
    yield m
    m.close()


def _raw_channel(mount) -> socket.socket:
    sock = socket.socket(socket.AF_UNIX, socket.SOCK_STREAM)
    sock.connect(mount._sock_path)
    sock.settimeout(10)
    return sock


def test_fs_error_stays_errno(mf):
    with pytest.raises(FsError) as ei:
        mf.mount.call("lookup", 1, "does-not-exist")
    assert ei.value.errno == Errno.ENOENT


def test_poisoned_op_surfaces_and_fails_only_its_channel(mf):
    """An op the fs module does not have is a programming error, not an
    fs refusal: the caller gets the exception type by name (never a
    silent hang, never an errno masquerade), that channel dies, and the
    daemon keeps serving fresh channels."""
    mf.view.write_file("/keep", b"before the poison")
    errs = []

    def poison():
        # own thread -> own channel: only this channel gets failed
        try:
            mf.mount.call("definitely_not_an_op")
        except Exception as e:  # noqa: BLE001 — collected for assertion
            errs.append(e)

    t = threading.Thread(target=poison)
    t.start()
    t.join(timeout=30)
    assert not t.is_alive(), "poisoned op hung instead of surfacing"
    assert len(errs) == 1 and isinstance(errs[0], RuntimeError)
    assert "AttributeError" in str(errs[0])
    # the daemon survived and serves other channels
    assert mf.view.read_file("/keep") == b"before the poison"
    assert mf.mount.ctl("stats")["generation"] == 1


def test_undecodable_frame_fails_channel_not_daemon(mf):
    """Garbage bytes in a frame used to kill the whole daemon. Now the
    sender gets an error frame, its channel closes, everyone else lives."""
    mf.view.write_file("/alive", b"yes")
    raw = _raw_channel(mf.mount)
    try:
        raw.sendall(struct.pack("<I", 9) + b"\x93garbage!")
        status, payload = _recv(raw)
        assert status == "error" and "undecodable" in payload
        # daemon closed this channel
        assert raw.recv(1) == b""
    finally:
        raw.close()
    assert mf.view.read_file("/alive") == b"yes"


def test_malformed_message_fails_channel_not_daemon(mf):
    """A frame that unpickles to the wrong shape (not (op, args, kw))
    gets the same treatment: error reply, channel failed, daemon fine."""
    raw = _raw_channel(mf.mount)
    try:
        _send(raw, {"not": "a request"})
        status, payload = _recv(raw)
        assert status == "error" and "malformed" in payload
        assert raw.recv(1) == b""
    finally:
        raw.close()
    assert mf.mount.ctl("stats")["drains"] >= 0


def test_unpicklable_scalar_args_fail_loudly(mf):
    """A request whose pickled args decode but blow up inside the op
    (wrong types) is surfaced as the exception, not a hang."""
    with pytest.raises(RuntimeError, match="TypeError|AttributeError"):
        mf.mount.call("read", object=None)
