"""POSIX semantics over every mount binding (bento / vfs / fuse / ext4like)."""

import numpy as np
import pytest

from repro.core.interface import Errno, FsError
from repro.fs.mounts import ALL_KINDS, make_mount

pytestmark = pytest.mark.parametrize("kind", ALL_KINDS)


@pytest.fixture
def mnt(kind):
    mf = make_mount(kind, n_blocks=8192)
    yield mf
    mf.close()


def test_basic_files(mnt, kind):
    v = mnt.view
    v.write_file("/x.txt", b"hello")
    assert v.read_file("/x.txt") == b"hello"
    v.write_file("/x.txt", b"HE", off=0, create=False)
    assert v.read_file("/x.txt") == b"HEllo"
    v.append("/x.txt", b"!")
    assert v.read_file("/x.txt") == b"HEllo!"
    assert v.stat("/x.txt").size == 6


def test_dirs_and_errors(mnt, kind):
    v = mnt.view
    v.makedirs("/a/b/c")
    assert v.listdir("/a/b") == ["c"]
    with pytest.raises(FsError) as e:
        v.read_file("/a/nope")
    assert e.value.errno == Errno.ENOENT
    with pytest.raises(FsError) as e:
        v.mkdir("/a/b")
    assert e.value.errno == Errno.EEXIST
    with pytest.raises(FsError) as e:
        v.rmdir("/a/b")  # not empty
    assert e.value.errno == Errno.ENOTEMPTY
    with pytest.raises(FsError) as e:
        v.unlink("/a/b")  # it's a dir
    assert e.value.errno == Errno.EISDIR
    v.rmdir("/a/b/c")
    v.rmdir("/a/b")
    assert v.listdir("/a") == []


def test_rename(mnt, kind):
    v = mnt.view
    v.makedirs("/d1")
    v.makedirs("/d2")
    v.write_file("/d1/f", b"payload")
    v.rename("/d1/f", "/d2/g")
    assert not v.exists("/d1/f")
    assert v.read_file("/d2/g") == b"payload"


def test_sparse_and_offsets(mnt, kind):
    v = mnt.view
    v.create("/sparse")
    v.write_file("/sparse", b"end", off=100_000, create=False)
    data = v.read_file("/sparse")
    assert len(data) == 100_003
    assert data[:10] == bytes(10)  # hole reads as zeros
    assert data[-3:] == b"end"


def test_large_file_double_indirect(mnt, kind):
    """> NDIRECT + NINDIRECT blocks exercises the double-indirect path
    (the paper's 4 GB extension, scaled to this device)."""
    if kind == "fuse":
        pytest.skip("slow over the bridge; covered by other mounts")
    v = mnt.view
    rng = np.random.default_rng(5)
    blob = rng.integers(0, 256, (12 + 1024 + 40) * 4096, dtype=np.uint8).tobytes()
    v.write_file("/big.bin", blob)
    v.fsync("/big.bin")
    got = v.read_file("/big.bin")
    assert got == blob
    assert v.stat("/big.bin").size == len(blob)


def test_unlink_frees_space(mnt, kind):
    v = mnt.view
    before = v.statfs()["free_blocks_est"]
    v.write_file("/tmpfile", b"z" * (64 * 4096))
    mid = v.statfs()["free_blocks_est"]
    assert mid < before
    v.unlink("/tmpfile")
    after = v.statfs()["free_blocks_est"]
    assert after >= before - 2  # inode/dir metadata may keep a block


def test_many_files_readdir(mnt, kind):
    v = mnt.view
    v.makedirs("/many")
    n = 20 if kind == "fuse" else 150
    for i in range(n):
        v.write_file(f"/many/f{i:04d}", b"x")
    names = v.listdir("/many")
    assert len(names) == n
    assert sorted(names) == [f"f{i:04d}" for i in range(n)]


def test_truncate(mnt, kind):
    v = mnt.view
    v.write_file("/t", b"0123456789")
    v.truncate("/t", 4)
    assert v.read_file("/t") == b"0123"
    v.truncate("/t", 0)
    assert v.read_file("/t") == b""
