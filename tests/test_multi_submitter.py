"""Multi-submitter BentoQueues: per-thread SQs draining into one OpGate
crossing (io_uring SQPOLL-style).

The deterministic proofs use the freeze-the-gate trick: with the gate
frozen, N threads' submissions pile up in the mount's pending queue, and
the thaw lets one drainer carry them all — so "crossings ≪ submissions"
is asserted exactly, not statistically. Correctness is pinned by a
scalar-vs-threaded differential (disjoint per-thread subtrees must land
byte-identical to a sequential reference run), chains are shown to never
split across a drain or merge across submitters (one journal chain
reservation per create→write pair, exactly), and an upgrade mid-storm
still swaps exactly once with no lost or duplicated completions.
"""

import threading
import time

import pytest

from repro.core.capability import SuperBlockCap
from repro.core.interface import (Attr, BentoFilesystem, Errno, FileKind,
                                  FsError, PrevResult, SQE_LINK,
                                  SubmissionEntry)
from repro.core.registry import Mount, SubmitterQueue
from repro.core.services import kernel_binding
from repro.core.upgrade import upgrade
from repro.fs.blockdev import MemBlockDevice
from repro.fs.mounts import make_mount
from repro.fs.xv6 import Xv6FileSystem, Xv6Options


def _join_all(threads, timeout=30):
    for t in threads:
        t.join(timeout)
    assert not any(t.is_alive() for t in threads), "worker deadlocked"


def _wait_until(pred, timeout=5.0):
    deadline = time.time() + timeout
    while not pred():
        assert time.time() < deadline, "condition never became true"
        time.sleep(0.002)


# --- deterministic coalescing: freeze, pile up, thaw -----------------------------


def test_frozen_gate_coalesces_pending_submissions():
    """4 submissions staged while the gate is frozen drain in ≤ 2
    crossings after the thaw (the drainer may have grabbed its own batch
    before freezing blocked it; everything else rides one drain)."""
    mf = make_mount("bento", n_blocks=4096)
    v = mf.view
    v.write_file("/f", b"d" * (16 * 4096))
    v.fsync("/f")
    ino = v.stat("/f").ino
    m = mf.mount
    g0, s0, d0 = m.gate.crossings, m.mq_submissions, m.mq_drains
    m.gate.freeze()
    results = {}

    def worker(t):
        comps = m.submit([SubmissionEntry("read", (ino, i * 4096, 4096),
                                          user_data=(t, i))
                          for i in range(8)])
        results[t] = comps

    threads = [threading.Thread(target=worker, args=(t,), daemon=True)
               for t in range(4)]
    for t in threads:
        t.start()
    _wait_until(lambda: m.mq_submissions - s0 == 4)
    time.sleep(0.05)  # let the drainer reach its (blocked) gate.enter
    m.gate.thaw()
    _join_all(threads)
    assert m.mq_drains - d0 <= 2, "pending submissions did not coalesce"
    assert m.gate.crossings - g0 <= 2
    for t in range(4):
        assert [c.user_data for c in results[t]] == [(t, i) for i in range(8)]
        assert all(c.ok and c.result == b"d" * 4096 for c in results[t])
    mf.close()


def test_sqpoll_thread_drains_frozen_backlog():
    """Same proof with the dedicated SQPOLL drainer: submitters only
    append; the poller carries the whole backlog."""
    mf = make_mount("bento", n_blocks=4096)
    v = mf.view
    v.write_file("/f", b"q" * 4096)
    ino = v.stat("/f").ino
    m = mf.mount
    m.start_sqpoll(idle_us=0)
    try:
        d0, s0 = m.mq_drains, m.mq_submissions
        m.gate.freeze()
        results = {}

        def worker(t):
            results[t] = m.submit([SubmissionEntry("read", (ino, 0, 1),
                                                   user_data=t)])

        threads = [threading.Thread(target=worker, args=(t,), daemon=True)
                   for t in range(4)]
        for t in threads:
            t.start()
        _wait_until(lambda: m.mq_submissions - s0 == 4)
        time.sleep(0.05)
        m.gate.thaw()
        _join_all(threads)
        assert m.mq_drains - d0 <= 2
        for t in range(4):
            assert results[t][0].ok and results[t][0].result == b"q"
    finally:
        m.stop_sqpoll()
    # opportunistic mode resumes: an uncontended submit still works
    assert m.submit([SubmissionEntry("statfs", ())])[0].ok
    mf.close()


def test_chains_never_split_or_merge_across_drains():
    """Concurrent chained submissions: one journal chain reservation per
    chain, exactly — coalesced drains must not merge two submitters'
    chains, and a drain boundary must not split one."""
    mf = make_mount("bento", n_blocks=8192)
    m = mf.mount
    j = m.module.journal
    m.gate.freeze()
    ch0, s0 = j.chains, m.mq_submissions
    results = {}

    def worker(t):
        results[t] = m.submit([
            SubmissionEntry("create", (1, f"c{t}"), user_data=(t, "c"),
                            flags=SQE_LINK),
            SubmissionEntry("write", (PrevResult("ino"), 0,
                                      bytes([65 + t]) * 3000),
                            user_data=(t, "w"), flags=SQE_LINK),
            SubmissionEntry("fsync", (PrevResult("ino", back=2),),
                            user_data=(t, "s")),
        ])

    threads = [threading.Thread(target=worker, args=(t,), daemon=True)
               for t in range(4)]
    for t in threads:
        t.start()
    _wait_until(lambda: m.mq_submissions - s0 == 4)
    time.sleep(0.05)
    m.gate.thaw()
    _join_all(threads)
    assert j.chains - ch0 == 4          # one reservation per submitter
    for t in range(4):
        assert all(c.ok for c in results[t]), results[t]
    v = mf.view
    for t in range(4):
        assert v.read_file(f"/c{t}") == bytes([65 + t]) * 3000
    mf.close()


# --- differential equivalence: threaded == sequential ----------------------------


def _tree_dump(v, path="/"):
    out = {}
    for name in sorted(v.listdir(path)):
        p = f"{path.rstrip('/')}/{name}"
        st = v.stat(p)
        if st.kind == FileKind.DIR:
            out[name] = _tree_dump(v, p)
        else:
            out[name] = v.read_file(p)
    return out


def _thread_program(v, t):
    """One thread's workload, confined to its own subtree (so any
    interleaving must produce the same final tree)."""
    v.makedirs(f"/w{t}")
    v.create_and_write_many(
        [(f"/w{t}/f{i}", bytes([97 + t]) * (256 * (i + 1)))
         for i in range(8)], fsync=True)
    v.unlink_many([f"/w{t}/f{i}" for i in (1, 4)])
    v.write_many([(f"/w{t}/f0", 0, b"patched!")], create=False, fsync=True)
    got = v.read_many([(f"/w{t}/f0", 0, 8)])
    assert got == [b"patched!"]
    stats = v.stat_many([f"/w{t}/f{i}" for i in (0, 2, 3)])
    assert all(s.nlink == 1 for s in stats)


@pytest.mark.parametrize("sqpoll", [False, True, "parallel"])
def test_threaded_equals_sequential_tree(sqpoll):
    mf = make_mount("bento", n_blocks=8192)
    if sqpoll == "parallel":
        mf.mount.start_sqpoll(parallel=4)   # footprint-scheduled workers
    elif sqpoll:
        mf.mount.start_sqpoll()
    errors = []

    def worker(t):
        try:
            _thread_program(mf.view, t)
        except Exception as e:  # noqa: BLE001 — surfaced below
            errors.append(f"t{t}: {type(e).__name__}: {e}")

    threads = [threading.Thread(target=worker, args=(t,), daemon=True)
               for t in range(4)]
    for t in threads:
        t.start()
    _join_all(threads)
    if sqpoll:
        mf.mount.stop_sqpoll()
    assert not errors, errors
    threaded = _tree_dump(mf.view)
    mf.close()

    ref = make_mount("bento", n_blocks=8192)
    for t in range(4):
        _thread_program(ref.view, t)
    sequential = _tree_dump(ref.view)
    ref.close()
    assert threaded == sequential


# --- upgrade during a threaded submission storm ----------------------------------


def test_upgrade_mid_storm_swaps_once_and_loses_nothing():
    """N threads submitting chains while an upgrade quiesces and swaps the
    table: every chain completes fully (from a single generation — never
    split across the swap), exactly one generation bump, files intact."""
    mf = make_mount("bento", n_blocks=8192)
    v = mf.view
    m = mf.mount
    gen0 = m.generation
    errors = []
    started = threading.Event()

    def worker(t):
        try:
            v.makedirs(f"/u{t}")
            started.set()
            for r in range(6):
                out = v.create_and_write_many(
                    [(f"/u{t}/r{r}_{i}", bytes([48 + t]) * 512)
                     for i in range(4)], fsync=True)
                assert out == [512] * 4
        except Exception as e:  # noqa: BLE001
            errors.append(f"t{t}: {type(e).__name__}: {e}")

    threads = [threading.Thread(target=worker, args=(t,), daemon=True)
               for t in range(4)]
    for t in threads:
        t.start()
    started.wait(5)
    time.sleep(0.02)  # let the storm develop
    stats = upgrade(m, Xv6FileSystem(Xv6Options()))
    _join_all(threads)
    assert not errors, errors
    assert m.generation == gen0 + 1
    assert stats["total_s"] < 30
    for t in range(4):
        assert len(v.listdir(f"/u{t}")) == 24
        assert v.read_file(f"/u{t}/r5_3") == bytes([48 + t]) * 512
    mf.close()


# --- drainer-thread reentrancy and failure recovery ------------------------------


class _StubFs(BentoFilesystem):
    """Minimal module for dispatch-machinery tests: getattr answers, and
    submit_batch can be armed to raise (an implementation bug)."""

    NAME, VERSION = "stub", 1

    def __init__(self):
        self.boom = False
        self.mount_ref = None
        self.nested_ok = None

    def init(self, sb: SuperBlockCap, services) -> None:
        pass

    def getattr(self, ino):
        return Attr(ino=ino, kind=FileKind.FILE, size=0, nlink=1)

    def lookup(self, parent, name):
        raise FsError(Errno.ENOENT, name)

    def create(self, parent, name):
        return Attr(ino=2, kind=FileKind.FILE, size=0, nlink=1)

    def mkdir(self, parent, name):
        return Attr(ino=3, kind=FileKind.DIR, size=0, nlink=2)

    def unlink(self, parent, name):
        pass

    def rmdir(self, parent, name):
        pass

    def rename(self, parent, name, newparent, newname):
        pass

    def readdir(self, ino):
        return []

    def read(self, ino, off, size):
        return b""

    def write(self, ino, off, data):
        return len(data)

    def truncate(self, ino, size):
        pass

    def fsync(self, ino):
        pass

    def statfs(self):
        # re-enter batched dispatch on the dispatching thread: must join
        # the outer crossing, not deadlock against our own drain
        if self.mount_ref is not None and self.nested_ok is None:
            self.nested_ok = False
            comps = self.mount_ref.submit(
                [SubmissionEntry("getattr", (1,))])
            self.nested_ok = comps[0].ok
        return {"blocks": 0}

    def submit_batch(self, entries):
        if self.boom:
            self.boom = False
            raise RuntimeError("injected module bug")
        return super().submit_batch(entries)


def _stub_mount():
    ks = kernel_binding(MemBlockDevice(64))
    fs = _StubFs()
    return Mount("stub", fs, ks), fs


@pytest.mark.parametrize("sqpoll", [False, True])
def test_nested_submit_on_drainer_thread_joins_crossing(sqpoll):
    m, fs = _stub_mount()
    fs.mount_ref = m
    if sqpoll:
        m.start_sqpoll(idle_us=0)
    try:
        comps = m.submit([SubmissionEntry("statfs", (), user_data="outer")])
        assert comps[0].ok
        assert fs.nested_ok is True
    finally:
        if sqpoll:
            m.stop_sqpoll()


def test_sqpoll_survives_module_bug_and_releases_role():
    """A module bug that kills the poller thread must not wedge the
    mount: the poisoned round's waiters see the bug, the poller's finally
    releases the drainer role, and the NEXT submission drains
    opportunistically."""
    m, fs = _stub_mount()
    m.start_sqpoll(idle_us=0)
    fs.boom = True
    with pytest.raises(RuntimeError, match="injected module bug"):
        m.submit([SubmissionEntry("getattr", (1,))])
    # poller died but released the role: submit must not block or fail
    comps = m.submit([SubmissionEntry("getattr", (2,))])
    assert comps[0].ok
    assert m._sqpoll is None and not m._mq_draining
    m.stop_sqpoll()  # no-op on the already-dead poller


def test_start_sqpoll_waits_for_inflight_opportunistic_drainer():
    """Installing the poller while an opportunistic drainer is mid-flight
    must wait for the role, not race it (two live drainers)."""
    mf = make_mount("bento", n_blocks=4096)
    v = mf.view
    v.write_file("/f", b"s" * 4096)
    ino = v.stat("/f").ino
    m = mf.mount
    m.gate.freeze()          # the drainer will block inside its crossing
    s0 = m.mq_submissions
    results = {}

    def submitter():
        results["comps"] = m.submit(
            [SubmissionEntry("read", (ino, 0, 1), user_data="r")])

    t = threading.Thread(target=submitter, daemon=True)
    t.start()
    _wait_until(lambda: m.mq_submissions - s0 == 1)
    started = threading.Event()

    def starter():
        m.start_sqpoll(idle_us=0)  # must block until the drainer is done
        started.set()

    st = threading.Thread(target=starter, daemon=True)
    st.start()
    time.sleep(0.05)
    assert not started.is_set(), "start_sqpoll raced a live drainer"
    m.gate.thaw()
    _join_all([t, st])
    assert started.is_set()
    assert results["comps"][0].ok
    # poller owns the role now and still serves
    assert m.submit([SubmissionEntry("statfs", ())])[0].ok
    m.stop_sqpoll()
    mf.close()


def test_drainer_exception_reaches_every_waiter_and_role_recovers():
    """A module bug raised mid-drain must surface in EVERY submitter whose
    submission rode that drain, and the drainer role must not stay wedged
    — the next submission drains normally."""
    m, fs = _stub_mount()
    m.gate.freeze()
    s0 = m.mq_submissions
    outcomes = {}

    def worker(t):
        try:
            outcomes[t] = m.submit([SubmissionEntry("getattr", (1,),
                                                    user_data=t)])
        except RuntimeError as e:
            outcomes[t] = e

    threads = [threading.Thread(target=worker, args=(t,), daemon=True)
               for t in range(2)]
    for t in threads:
        t.start()
    _wait_until(lambda: m.mq_submissions - s0 == 2)
    fs.boom = True
    time.sleep(0.05)
    m.gate.thaw()
    _join_all(threads)
    # both riders of the poisoned drain saw the bug (or, if the drains
    # split, exactly the poisoned one did and the other completed)
    bugs = [o for o in outcomes.values() if isinstance(o, RuntimeError)]
    oks = [o for o in outcomes.values() if not isinstance(o, RuntimeError)]
    assert bugs, "the injected bug vanished"
    for o in oks:
        assert o[0].ok
    # role recovered: a fresh submission completes
    assert m.submit([SubmissionEntry("getattr", (7,))])[0].ok


# --- adaptive SQPOLL gather window ------------------------------------------------


def test_sqpoll_adaptive_idle_state_machine():
    """The adaptation rule itself, exercised deterministically: lone-
    submission drains halve the gather window (snapping to 0 below 1 µs),
    the first coalescing drain (≥2 submissions) restores the configured
    window, and idle_us=0 / adaptive=False configurations never adapt."""
    mf = make_mount("bento", n_blocks=2048)
    m = mf.mount
    m.start_sqpoll(idle_us=400)
    try:
        base = m._sqpoll_idle_base_s
        assert base == pytest.approx(400e-6)
        m._adapt_idle(1)
        assert m._sqpoll_idle_s == pytest.approx(base / 2)
        m._adapt_idle(1)
        assert m._sqpoll_idle_s == pytest.approx(base / 4)
        m._adapt_idle(4)                      # full drain: restore
        assert m._sqpoll_idle_s == pytest.approx(base)
        for _ in range(12):                   # decays to exactly zero
            m._adapt_idle(0 or 1)
        assert m._sqpoll_idle_s == 0.0
        m._adapt_idle(2)
        assert m._sqpoll_idle_s == pytest.approx(base)
    finally:
        m.stop_sqpoll()
    # idle_us=0: nothing to adapt
    m.start_sqpoll(idle_us=0)
    try:
        m._adapt_idle(1)
        assert m._sqpoll_idle_s == 0.0
    finally:
        m.stop_sqpoll()
    # adaptive off: window pinned
    m.start_sqpoll(idle_us=300, adaptive=False)
    try:
        m._adapt_idle(1)
        assert m._sqpoll_idle_s == pytest.approx(300e-6)
    finally:
        m.stop_sqpoll()
    mf.close()


def test_sqpoll_adaptive_idle_decays_then_frozen_pileup_restores():
    """Integration, still deterministic: sequential lone submissions each
    drain alone (submit blocks until completion, so drains serialize) and
    the window halves per drain; then the frozen-gate trick piles 4
    submissions into ONE drain call, which restores the window."""
    mf = make_mount("bento", n_blocks=4096)
    v = mf.view
    v.write_file("/f", b"a" * 4096)
    ino = v.stat("/f").ino
    m = mf.mount
    m.start_sqpoll(idle_us=400)
    try:
        base = m._sqpoll_idle_base_s
        for _ in range(3):  # three lone drains: base/2, base/4, base/8
            assert m.submit([SubmissionEntry("read", (ino, 0, 1))])[0].ok
        assert m._sqpoll_idle_s == pytest.approx(base / 8)
        m.gate.freeze()
        s0 = m.mq_submissions
        results = {}

        def worker(t):
            results[t] = m.submit([SubmissionEntry("read", (ino, 0, 1),
                                                   user_data=t)])

        threads = [threading.Thread(target=worker, args=(t,), daemon=True)
                   for t in range(4)]
        for t in threads:
            t.start()
        _wait_until(lambda: m.mq_submissions - s0 == 4)
        time.sleep(0.05)
        m.gate.thaw()
        _join_all(threads)
        # all 4 rode one _drain_pending call (the poller loops until the
        # queue is empty before adapting), so the full-drain rule fired
        assert m._sqpoll_idle_s == pytest.approx(base)
        for t in range(4):
            assert results[t][0].ok and results[t][0].result == b"a"
    finally:
        m.stop_sqpoll()
    mf.close()


# --- SQPOLL backlog must skip the gather window (starvation fix) ------------------


def test_sqpoll_backlog_skips_gather_window():
    """The drainer-starvation fix, pinned deterministically: a submission
    already pending when the poller checks its queue must be drained
    IMMEDIATELY — the gather window exists to let a batch accumulate, but
    sleeping it when a backlog has already accumulated just starves the
    waiting submitters. Pre-stage a pending submission, then start the
    poller with an absurd 5-second window: the backlog path must skip
    the sleep (counter increments) and complete promptly. The pre-fix
    loop slept the full window here and this test timed out."""
    from repro.core.registry import _PendingSubmission

    mf = make_mount("bento", n_blocks=2048)
    m = mf.mount
    sub = _PendingSubmission([SubmissionEntry("statfs", (),
                                              user_data="backlog")])
    with m._mq_cv:
        m._mq_pending.append(sub)
    k0 = m.mq_gather_skips
    t0 = time.time()
    m.start_sqpoll(idle_us=5_000_000, adaptive=False)
    try:
        _wait_until(lambda: sub.comps is not None or sub.error is not None,
                    timeout=2.0)
        assert time.time() - t0 < 2.0  # never slept the 5s window
        assert sub.error is None
        assert sub.comps[0].ok and sub.comps[0].user_data == "backlog"
        assert m.mq_gather_skips - k0 == 1
    finally:
        m.stop_sqpoll()
    mf.close()


def test_sqpoll_idle_queue_still_gathers():
    """The complement: with NO backlog at wake-up the gather window still
    applies (lone submissions coalesce opportunistically), so the skip
    counter stays put on an idle→submit→drain round trip."""
    mf = make_mount("bento", n_blocks=2048)
    m = mf.mount
    m.start_sqpoll(idle_us=200, adaptive=False)
    try:
        time.sleep(0.1)   # let the poller settle into its idle wait
        k0 = m.mq_gather_skips
        assert m.submit([SubmissionEntry("statfs", ())])[0].ok
        assert m.mq_gather_skips == k0
    finally:
        m.stop_sqpoll()
    mf.close()


# --- parallel drain: worker pool behind the drainer's single crossing -------------


def test_parallel_drain_pool_correctness_and_lifecycle():
    """4 submitters pile up behind a frozen gate; the thaw drains them
    through the footprint-scheduled worker pool. Completions and data
    must be exact, the gate is still crossed once per drain (workers run
    INSIDE the drainer's crossing, never their own), and unmount retires
    the pool."""
    mf = make_mount("bento", n_blocks=8192)
    m = mf.mount
    m.enable_parallel_drain(4)
    assert m._drain_pool is not None
    v = mf.view
    v.write_file("/f", b"d" * (8 * 4096))
    v.fsync("/f")
    ino = v.stat("/f").ino
    m.gate.freeze()
    s0, g0, d0 = m.mq_submissions, m.gate.crossings, m.mq_drains
    results = {}

    def worker(t):
        if t == 0:   # one mutating chain among read-only submitters
            results[t] = m.submit([
                SubmissionEntry("create", (1, "n0"), user_data="c",
                                flags=SQE_LINK),
                SubmissionEntry("write", (PrevResult("ino"), 0,
                                          b"x" * 3000), user_data="w"),
            ])
        else:
            results[t] = m.submit(
                [SubmissionEntry("read", (ino, i * 4096, 4096),
                                 user_data=(t, i)) for i in range(8)])

    threads = [threading.Thread(target=worker, args=(t,), daemon=True)
               for t in range(4)]
    for t in threads:
        t.start()
    _wait_until(lambda: m.mq_submissions - s0 == 4)
    time.sleep(0.05)
    m.gate.thaw()
    _join_all(threads)
    assert m.mq_drains - d0 <= 2, "pileup did not coalesce"
    assert m.gate.crossings - g0 <= 2, "a drain worker crossed the gate"
    assert all(c.ok for c in results[0]), results[0]
    for t in (1, 2, 3):
        assert [c.user_data for c in results[t]] == \
            [(t, i) for i in range(8)]
        assert all(c.ok and c.result == b"d" * 4096 for c in results[t])
    assert v.read_file("/n0") == b"x" * 3000
    mf.close()                       # unmount retires the drain workers
    assert m._drain_pool is None


def test_enable_parallel_drain_zero_disables():
    mf = make_mount("bento", n_blocks=2048)
    m = mf.mount
    m.enable_parallel_drain(4)
    assert m._drain_pool is not None
    m.enable_parallel_drain(0)
    assert m._drain_pool is None and not m._drain_tids
    # still serves serially afterwards
    assert m.submit([SubmissionEntry("statfs", ())])[0].ok
    mf.close()


# --- SubmitterQueue surfaces ------------------------------------------------------


def test_submitter_queue_is_thread_local_and_counts():
    mf = make_mount("bento", n_blocks=2048)
    m = mf.mount
    ids = {}

    def worker(t):
        q = m.submitter_queue()
        ids[t] = q                       # hold the object (id() would be
        #   reusable after a dead thread's queue is collected)
        assert q is m.submitter_queue()  # stable within the thread
        q.prep("statfs", user_data=t)
        q.submit()
        comps = q.drain()
        assert comps[0].ok and comps[0].user_data == t
        assert q.submits == 1 and q.entries_submitted == 1

    threads = [threading.Thread(target=worker, args=(t,), daemon=True)
               for t in range(3)]
    for t in threads:
        t.start()
    _join_all(threads)
    assert len({id(q) for q in ids.values()}) == 3   # one queue per thread
    mf.close()


def test_posix_view_rides_thread_local_sq():
    mf = make_mount("bento", n_blocks=4096)
    v = mf.view
    v.write_file("/f", b"z" * 8192)
    qs = {}

    def worker(t):
        assert v.read_many([("/f", 0, 4096)]) == [b"z" * 4096]
        qs[t] = v._tls.sq

    threads = [threading.Thread(target=worker, args=(t,), daemon=True)
               for t in range(2)]
    for t in threads:
        t.start()
    _join_all(threads)
    assert isinstance(qs[0], SubmitterQueue)
    assert qs[0] is not qs[1]            # per-thread queues
    assert qs[0].submits >= 1 and qs[1].submits >= 1
    mf.close()


# --- the FUSE daemon drains all channels per crossing -----------------------------


def test_fuse_threads_submit_on_private_channels():
    mf = make_mount("fuse", n_blocks=2048)
    v = mf.view
    v.write_file("/f", b"m" * (8 * 4096))
    v.fsync("/f")
    ino = v.stat("/f").ino
    m = mf.mount
    errors = []
    start = threading.Barrier(4)

    def worker(t):
        try:
            start.wait()
            for r in range(6):
                comps = m.submit([
                    SubmissionEntry("read", (ino, i * 4096, 4096),
                                    user_data=(t, r, i)) for i in range(8)])
                assert all(c.ok and c.result == b"m" * 4096 for c in comps)
                assert [c.user_data for c in comps] == \
                    [(t, r, i) for i in range(8)]
        except Exception as e:  # noqa: BLE001
            errors.append(f"t{t}: {type(e).__name__}: {e}")

    threads = [threading.Thread(target=worker, args=(t,), daemon=True)
               for t in range(4)]
    for t in threads:
        t.start()
    _join_all(threads)
    assert not errors, errors
    stats = m.ctl("stats")
    assert stats["batch_requests"] >= 24          # every submission served
    assert stats["drains"] <= stats["batch_requests"]
    mf.close()


def test_fuse_scalar_ops_ride_private_channels():
    """Scalar dispatch through the FUSE bridge uses the same per-thread
    channels as batched submissions (multi-queue /dev/fuse): a 4-thread
    scalar storm must stay correct with one channel per thread (plus the
    shutdown-sentinel primary), every call counted daemon-side, and a
    deterministic two-channel double-send must land in one service round
    (the ``multi_channel_scalar_rounds`` win)."""
    from repro.fs.fusebridge import _recv, _send

    mf = make_mount("fuse", n_blocks=2048)
    v = mf.view
    m = mf.mount
    v.write_file("/f", b"k" * 4096)
    base = m.ctl("stats")["scalar_requests"]
    errors = []
    start = threading.Barrier(4)

    def worker(t):
        try:
            start.wait()
            for r in range(10):
                st = v.stat("/f")
                assert st.size == 4096
                assert v.read_file("/f", off=r * 16, size=16) == b"k" * 16
        except Exception as e:  # noqa: BLE001
            errors.append(f"t{t}: {type(e).__name__}: {e}")

    threads = [threading.Thread(target=worker, args=(t,), daemon=True)
               for t in range(4)]
    for t in threads:
        t.start()
    _join_all(threads)
    assert not errors, errors
    # one private channel per worker thread + the primary (and this
    # thread's own channel from the setup/ctl calls)
    assert len(m._channels) >= 6
    stats = m.ctl("stats")
    assert stats["scalar_requests"] - base >= 80   # every scalar counted
    # deterministic multi-channel round: park a request on each of two
    # fresh channels before reading either reply — the daemon's select
    # collects both in one round (retry the race where it wakes between
    # the sends)
    chans = [m._connect(deadline_s=10) for _ in range(2)]
    rounds0 = stats["multi_channel_scalar_rounds"]
    try:
        for _ in range(50):
            for ch in chans:
                _send(ch, ("getattr", (1,), {}))
            for ch in chans:
                status, _payload = _recv(ch)
                assert status == "ok"
            if m.ctl("stats")["multi_channel_scalar_rounds"] > rounds0:
                break
        else:
            raise AssertionError(
                "two-channel scalars never shared a service round")
    finally:
        for ch in chans:
            ch.close()
    mf.close()
