"""Unit tests for the content-addressed data plane (``fs/blockstore.py``).

The crash story lives in ``tests/test_crash_torture.py`` (torture_dedup)
and the torn-write detection sweeps in ``tests/test_fs_crash.py``; this
file pins the in-memory contracts: sharing and CoW bookkeeping, free-path
refcounting, cold-remount index reload, upgrade state transfer, the
reserved index-file name, per-submitter attribution, and the plain-mount
bit-identity guarantee.
"""

import pytest

from repro.core.interface import Errno, FsError, ROOT_INO
from repro.core.upgrade import upgrade
from repro.fs.blockstore import DEDUP_TABLE_NAME
from repro.fs.ext4like import Ext4LikeFileSystem
from repro.fs.mounts import DEDUP_KINDS, make_mount
from repro.fs.xv6 import Xv6FileSystem, Xv6Options

A = b"a" * 4096
B = b"b" * 4096
C = b"c" * 4096


def _mount(kind="dedup-bento"):
    return make_mount(kind, n_blocks=4096)


def _store(mf):
    return mf.mount.module._blockstore


@pytest.mark.parametrize("kind", DEDUP_KINDS)
def test_identical_blocks_share_physical_storage(kind):
    mf = _mount(kind)
    try:
        v = mf.view
        free0 = v.statfs()["free_blocks_est"]
        v.write_file("/one", A + B)
        v.fsync("/one")
        v.write_file("/two", A + B)       # byte-identical: should share
        v.fsync("/two")
        sf = v.statfs()
        physical = free0 - sf["free_blocks_est"]
        assert physical == 2, f"4 logical blocks took {physical} physical"
        assert sf["dedup_hits"] == 2
        assert sf["dedup_shared_refs"] == 2
        assert v.read_file("/one") == A + B
        assert v.read_file("/two") == A + B
    finally:
        mf.close()


def test_cow_break_isolates_sharers():
    mf = _mount()
    try:
        v = mf.view
        v.write_file("/one", A + A)       # self-dedup: one physical block
        v.fsync("/one")
        v.write_file("/two", A)
        v.fsync("/two")
        assert v.statfs()["dedup_shared_refs"] == 2
        v.write_file("/two", C, create=False)   # must not bleed into /one
        v.fsync("/two")
        assert v.read_file("/one") == A + A
        assert v.read_file("/two") == C
        assert v.statfs()["dedup_cow_breaks"] >= 1
    finally:
        mf.close()


def test_release_drops_refs_and_frees_last():
    mf = _mount()
    try:
        v = mf.view
        free0 = v.statfs()["free_blocks_est"]
        v.write_file("/one", A + B)
        v.fsync("/one")
        v.write_file("/two", A + B)
        v.fsync("/two")
        v.unlink("/two")                  # shared refs drop, blocks stay
        v.fsync("/one")
        assert v.read_file("/one") == A + B
        assert v.statfs()["dedup_shared_refs"] == 0
        v.unlink("/one")                  # last refs: really freed
        mf.mount.module.flush()
        # free count returns to the post-attach baseline (the index file
        # itself predates free0): nothing leaked, nothing double-freed.
        # Churn may additionally have PUNCHED now-dead index blocks back
        # to the allocator (compaction), each one raising free by one —
        # account for the net index shrinkage explicitly.
        sf = v.statfs()
        store = _store(mf)
        punched = len(store._table_blocks) - sf["dedup_index_blocks"]
        assert punched >= 0
        assert sf["free_blocks_est"] == free0 + punched
        assert not store.refcnt
    finally:
        mf.close()


@pytest.mark.parametrize("kind", DEDUP_KINDS)
def test_index_survives_cold_remount(kind):
    """The index is journal-protected on-device state: a second module
    booted cold on the same device must reload identical refcounts and
    hashes (the crashsim audit relies on exactly this)."""
    mf = _mount(kind)
    try:
        v = mf.view
        v.write_file("/one", A + B + A)
        v.fsync("/one")
        fs1 = mf.mount.module
        fs1.flush()
        refcnt, hashval = dict(fs1._blockstore.refcnt), dict(
            fs1._blockstore.hashval)
        assert refcnt and hashval
        opts = Xv6Options(dedup=True)
        fs2 = (Xv6FileSystem(opts) if kind == "dedup-bento"
               else Ext4LikeFileSystem(opts))
        fs2.init(mf.services.superblock(), mf.services)
        assert fs2._blockstore.refcnt == refcnt
        assert fs2._blockstore.hashval == hashval
    finally:
        mf.close()


def test_upgrade_transfers_dedup_index_live():
    """§4.8 online upgrade with the data plane attached: the index rides
    ``extract_state``/``restore_state`` and sharing keeps working in the
    new module without a rescan."""
    mf = _mount()
    try:
        v = mf.view
        v.write_file("/one", A + B)
        v.fsync("/one")
        old = _store(mf)
        refcnt = dict(old.refcnt)
        upgrade(mf.mount, Xv6FileSystem(Xv6Options(dedup=True)))
        new = _store(mf)
        assert new is not old and new.refcnt == refcnt
        v.write_file("/two", A + B)       # dedups against pre-upgrade data
        v.fsync("/two")
        assert v.statfs()["dedup_shared_refs"] == 2
        assert v.read_file("/one") == A + B
    finally:
        mf.close()


def test_index_file_hidden_and_reserved():
    mf = _mount()
    try:
        v = mf.view
        v.write_file("/f", A)
        assert DEDUP_TABLE_NAME not in v.listdir("/")
        for op in (lambda: v.create("/" + DEDUP_TABLE_NAME),
                   lambda: v.unlink("/" + DEDUP_TABLE_NAME),
                   lambda: v.rename("/f", "/" + DEDUP_TABLE_NAME)):
            with pytest.raises(FsError) as ei:
                op()
            assert ei.value.errno == Errno.EPERM
    finally:
        mf.close()


def test_per_submitter_attribution():
    """Blocks flushed on behalf of a named SubmitterQueue are attributed
    to that submitter in the dedup stats, not to a thread id."""
    from repro.core.registry import SubmitterQueue

    mf = _mount()
    try:
        v = mf.view
        ino = v.create("/q").ino
        q = SubmitterQueue(mf.mount, submitter="alice")
        q.prep("write", ino, 0, A + B, user_data=1)
        q.prep("fsync", ino, user_data=2)
        q.submit()
        comps = list(q.drain())
        assert all(c.ok for c in comps)
        per = _store(mf).stats["by_submitter"]
        assert per.get("alice", {}).get("blocks", 0) >= 2
    finally:
        mf.close()


def _blocks(tag, n):
    """n blocks of 4096B each, globally unique content (no self-dedup)."""
    return b"".join((tag + i).to_bytes(4, "big") * 1024 for i in range(n))


def _full_walk(fs):
    """Walk every inode and rebuild, from metadata alone, the per-block
    file reference map and the full reachable set (meta blocks included)
    — the ground truth the statfs estimates are asserted against."""
    import repro.fs.layout as L

    store, geo = fs._blockstore, fs.geo
    refs, reachable = {}, set()
    for ino in range(1, geo.ninodes):
        di = fs._iget(ino)
        if di.type not in (L.T_FILE, L.T_DIR):
            continue
        counted = di.type == L.T_FILE and ino != store.table_ino
        cache = {}
        for bn in range((di.size + L.BSIZE - 1) // L.BSIZE):
            b = fs._bmap_ro(di, bn, cache)
            if b == 0:
                continue
            reachable.add(b)
            if counted:
                refs[b] = refs.get(b, 0) + 1
        l1, l2 = di.addrs[L.NDIRECT], di.addrs[L.NDIRECT + 1]
        if l1:
            reachable.add(l1)
        if l2:
            reachable.add(l2)
            with fs._bread(l2) as bh:
                raw = bytes(bh.data())
            for k in range(L.NINDIRECT):
                p = int.from_bytes(raw[4 * k: 4 * k + 4], "little")
                if p:
                    reachable.add(p)
    return refs, reachable


@pytest.mark.parametrize("kind", DEDUP_KINDS)
def test_free_estimates_match_full_walk_through_churn(kind):
    """The dedup-aware statfs bugfix, asserted against ground truth:
    ``free_blocks_est`` (physical, bitmap view) must equal data blocks
    minus everything reachable from some inode, and
    ``free_blocks_logical_est`` must add back exactly what sharing saved
    (walked refs minus unique blocks) — before churn, during sharing,
    and after a delete/overwrite churn cycle."""
    mf = _mount(kind)
    try:
        v, fs = mf.view, mf.mount.module

        def check():
            fs.flush()
            sf = v.statfs()
            refs, reachable = _full_walk(fs)
            assert sf["free_blocks_est"] == \
                sf["data_blocks"] - len(reachable), "physical est drifted"
            saved = sum(refs.values()) - len(refs)
            assert sf["dedup_saved_blocks"] == saved
            assert sf["free_blocks_logical_est"] == \
                sf["free_blocks_est"] + saved, "logical est drifted"

        check()                               # empty fs
        v.write_file("/a", A + B + A)
        v.fsync("/a")
        v.write_file("/b", A + C)
        v.fsync("/b")
        check()                               # sharing active
        v.unlink("/a")
        v.write_file("/b", C + C + B, create=False)
        v.fsync("/b")
        for i in range(6):
            v.write_file(f"/t{i}", _blocks(i << 20, 2))
            v.fsync(f"/t{i}")
        for i in range(6):
            v.unlink(f"/t{i}")
        check()                               # after churn
    finally:
        mf.close()


def test_index_compaction_punches_dead_block_and_remats():
    """Sustained churn that kills every live record in a table block must
    PUNCH it back to the allocator inside the churn op's own transaction
    (compactions stat, index-block count drops, hole sentinel in the
    table map), and a later write into the punched range must
    REMATERIALIZE the block transparently — with the free estimates
    matching a full walk across both transitions."""
    mf = _mount()
    try:
        v, fs, store = mf.view, mf.mount.module, _store(mf)

        def walk_free():
            sf = v.statfs()
            _, reachable = _full_walk(fs)
            assert sf["free_blocks_est"] == sf["data_blocks"] - len(reachable)
            return sf

        v.write_file("/churn", _blocks(0, 48))
        v.fsync("/churn")
        assert not store.compaction_due()     # everything still live
        nidx0 = v.statfs()["dedup_index_blocks"]
        v.unlink("/churn")                    # last live records die
        fs.flush()
        assert store.stats["compactions"] >= 1, "churn never compacted"
        assert store._table_blocks[0] == 0    # punched hole sentinel
        sf = walk_free()
        assert sf["dedup_index_blocks"] < nidx0
        assert sf["dedup_compactions"] == store.stats["compactions"]
        v.write_file("/re", _blocks(1 << 16, 8))   # back into the hole
        v.fsync("/re")
        assert store.stats["remats"] >= 1, "write onto hole never remat'd"
        assert store._table_blocks[0] != 0
        assert v.read_file("/re") == _blocks(1 << 16, 8)
        walk_free()
    finally:
        mf.close()


def test_compacted_index_survives_cold_remount():
    """A punched table block is durable on-device state: a second module
    booted cold must re-derive the same hole map (``_bmap_ro`` returns 0
    for the punched lbn) and identical refcounts and hashes."""
    mf = _mount()
    try:
        v, fs1 = mf.view, mf.mount.module
        v.write_file("/keep", _blocks(7 << 20, 3))
        v.fsync("/keep")
        # span past table block 0 (which /keep holds live) so the churn
        # file is the only thing live in table block 1
        v.write_file("/churn", _blocks(0, 560))
        v.fsync("/churn")
        v.unlink("/churn")
        fs1.flush()
        store = _store(mf)
        assert store.stats["compactions"] >= 1
        assert 0 in store._table_blocks      # a durable punched hole
        fs2 = Xv6FileSystem(Xv6Options(dedup=True))
        fs2.init(mf.services.superblock(), mf.services)
        assert fs2._blockstore._table_blocks == store._table_blocks
        assert fs2._blockstore.refcnt == store.refcnt
        assert fs2._blockstore.hashval == store.hashval
    finally:
        mf.close()


def test_plain_mounts_stay_bit_identical():
    """The opt-in guarantee: the same workload on a plain mount and a
    dedup mount produces identical file contents, and the plain device
    image carries no dedup index file at all."""
    plain, dedup = make_mount("bento", n_blocks=4096), _mount()
    try:
        for mf in (plain, dedup):
            mf.view.write_file("/x", A + A + B)
            mf.view.fsync("/x")
        assert plain.view.read_file("/x") == dedup.view.read_file("/x")
        assert plain.view.statfs().get("dedup_hits") is None
        root = plain.mount.module._iget(ROOT_INO)
        assert plain.mount.module._dirlookup(
            ROOT_INO, root, DEDUP_TABLE_NAME) is None
    finally:
        plain.close()
        dedup.close()
