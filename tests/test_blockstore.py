"""Unit tests for the content-addressed data plane (``fs/blockstore.py``).

The crash story lives in ``tests/test_crash_torture.py`` (torture_dedup)
and the torn-write detection sweeps in ``tests/test_fs_crash.py``; this
file pins the in-memory contracts: sharing and CoW bookkeeping, free-path
refcounting, cold-remount index reload, upgrade state transfer, the
reserved index-file name, per-submitter attribution, and the plain-mount
bit-identity guarantee.
"""

import pytest

from repro.core.interface import Errno, FsError, ROOT_INO
from repro.core.upgrade import upgrade
from repro.fs.blockstore import DEDUP_TABLE_NAME
from repro.fs.ext4like import Ext4LikeFileSystem
from repro.fs.mounts import DEDUP_KINDS, make_mount
from repro.fs.xv6 import Xv6FileSystem, Xv6Options

A = b"a" * 4096
B = b"b" * 4096
C = b"c" * 4096


def _mount(kind="dedup-bento"):
    return make_mount(kind, n_blocks=4096)


def _store(mf):
    return mf.mount.module._blockstore


@pytest.mark.parametrize("kind", DEDUP_KINDS)
def test_identical_blocks_share_physical_storage(kind):
    mf = _mount(kind)
    try:
        v = mf.view
        free0 = v.statfs()["free_blocks_est"]
        v.write_file("/one", A + B)
        v.fsync("/one")
        v.write_file("/two", A + B)       # byte-identical: should share
        v.fsync("/two")
        sf = v.statfs()
        physical = free0 - sf["free_blocks_est"]
        assert physical == 2, f"4 logical blocks took {physical} physical"
        assert sf["dedup_hits"] == 2
        assert sf["dedup_shared_refs"] == 2
        assert v.read_file("/one") == A + B
        assert v.read_file("/two") == A + B
    finally:
        mf.close()


def test_cow_break_isolates_sharers():
    mf = _mount()
    try:
        v = mf.view
        v.write_file("/one", A + A)       # self-dedup: one physical block
        v.fsync("/one")
        v.write_file("/two", A)
        v.fsync("/two")
        assert v.statfs()["dedup_shared_refs"] == 2
        v.write_file("/two", C, create=False)   # must not bleed into /one
        v.fsync("/two")
        assert v.read_file("/one") == A + A
        assert v.read_file("/two") == C
        assert v.statfs()["dedup_cow_breaks"] >= 1
    finally:
        mf.close()


def test_release_drops_refs_and_frees_last():
    mf = _mount()
    try:
        v = mf.view
        free0 = v.statfs()["free_blocks_est"]
        v.write_file("/one", A + B)
        v.fsync("/one")
        v.write_file("/two", A + B)
        v.fsync("/two")
        v.unlink("/two")                  # shared refs drop, blocks stay
        v.fsync("/one")
        assert v.read_file("/one") == A + B
        assert v.statfs()["dedup_shared_refs"] == 0
        v.unlink("/one")                  # last refs: really freed
        mf.mount.module.flush()
        # free count returns to the post-attach baseline (the index file
        # itself predates free0): nothing leaked, nothing double-freed
        assert v.statfs()["free_blocks_est"] == free0
        assert not _store(mf).refcnt
    finally:
        mf.close()


@pytest.mark.parametrize("kind", DEDUP_KINDS)
def test_index_survives_cold_remount(kind):
    """The index is journal-protected on-device state: a second module
    booted cold on the same device must reload identical refcounts and
    hashes (the crashsim audit relies on exactly this)."""
    mf = _mount(kind)
    try:
        v = mf.view
        v.write_file("/one", A + B + A)
        v.fsync("/one")
        fs1 = mf.mount.module
        fs1.flush()
        refcnt, hashval = dict(fs1._blockstore.refcnt), dict(
            fs1._blockstore.hashval)
        assert refcnt and hashval
        opts = Xv6Options(dedup=True)
        fs2 = (Xv6FileSystem(opts) if kind == "dedup-bento"
               else Ext4LikeFileSystem(opts))
        fs2.init(mf.services.superblock(), mf.services)
        assert fs2._blockstore.refcnt == refcnt
        assert fs2._blockstore.hashval == hashval
    finally:
        mf.close()


def test_upgrade_transfers_dedup_index_live():
    """§4.8 online upgrade with the data plane attached: the index rides
    ``extract_state``/``restore_state`` and sharing keeps working in the
    new module without a rescan."""
    mf = _mount()
    try:
        v = mf.view
        v.write_file("/one", A + B)
        v.fsync("/one")
        old = _store(mf)
        refcnt = dict(old.refcnt)
        upgrade(mf.mount, Xv6FileSystem(Xv6Options(dedup=True)))
        new = _store(mf)
        assert new is not old and new.refcnt == refcnt
        v.write_file("/two", A + B)       # dedups against pre-upgrade data
        v.fsync("/two")
        assert v.statfs()["dedup_shared_refs"] == 2
        assert v.read_file("/one") == A + B
    finally:
        mf.close()


def test_index_file_hidden_and_reserved():
    mf = _mount()
    try:
        v = mf.view
        v.write_file("/f", A)
        assert DEDUP_TABLE_NAME not in v.listdir("/")
        for op in (lambda: v.create("/" + DEDUP_TABLE_NAME),
                   lambda: v.unlink("/" + DEDUP_TABLE_NAME),
                   lambda: v.rename("/f", "/" + DEDUP_TABLE_NAME)):
            with pytest.raises(FsError) as ei:
                op()
            assert ei.value.errno == Errno.EPERM
    finally:
        mf.close()


def test_per_submitter_attribution():
    """Blocks flushed on behalf of a named SubmitterQueue are attributed
    to that submitter in the dedup stats, not to a thread id."""
    from repro.core.registry import SubmitterQueue

    mf = _mount()
    try:
        v = mf.view
        ino = v.create("/q").ino
        q = SubmitterQueue(mf.mount, submitter="alice")
        q.prep("write", ino, 0, A + B, user_data=1)
        q.prep("fsync", ino, user_data=2)
        q.submit()
        comps = list(q.drain())
        assert all(c.ok for c in comps)
        per = _store(mf).stats["by_submitter"]
        assert per.get("alice", {}).get("blocks", 0) >= 2
    finally:
        mf.close()


def test_plain_mounts_stay_bit_identical():
    """The opt-in guarantee: the same workload on a plain mount and a
    dedup mount produces identical file contents, and the plain device
    image carries no dedup index file at all."""
    plain, dedup = make_mount("bento", n_blocks=4096), _mount()
    try:
        for mf in (plain, dedup):
            mf.view.write_file("/x", A + A + B)
            mf.view.fsync("/x")
        assert plain.view.read_file("/x") == dedup.view.read_file("/x")
        assert plain.view.statfs().get("dedup_hits") is None
        root = plain.mount.module._iget(ROOT_INO)
        assert plain.mount.module._dirlookup(
            ROOT_INO, root, DEDUP_TABLE_NAME) is None
    finally:
        plain.close()
        dedup.close()
