"""POSIX rename semantics — the overwrite bugfix.

``rename`` onto an existing name used to raise EEXIST; POSIX says the
target is atomically REPLACED. These tests pin the full contract on every
mount kind (bento gate, vfs direct, ext4like dirindex, fuse daemon):
overwrite, kind checks (ENOTDIR/EISDIR), ENOTEMPTY, same-name no-op,
subtree-cycle EINVAL, nlink bookkeeping for moved/displaced directories,
displaced-inode block reclamation, and dcache coherence of the replaced
name. The per-crash-point atomicity proof lives in test_crash_torture.py.
"""

import pytest

from repro.core.interface import Errno, FileKind, FsError
from repro.fs.mounts import make_mount


@pytest.fixture(params=["bento", "vfs", "ext4like", "fuse"])
def mounted(request):
    n = 2048 if request.param == "fuse" else 4096
    mf = make_mount(request.param, n_blocks=n)
    yield mf
    mf.close()


def test_rename_overwrites_existing_file(mounted):
    v = mounted.view
    v.write_file("/a", b"moved-content")
    v.write_file("/b", b"displaced")
    ia = v.stat("/a").ino
    v.rename("/a", "/b")
    assert not v.exists("/a")
    assert v.read_file("/b") == b"moved-content"
    assert v.stat("/b").ino == ia          # same inode under the new name
    assert sorted(v.listdir("/")) == ["b"]


def test_rename_overwrite_frees_displaced_blocks(mounted):
    v = mounted.view
    v.write_file("/a", b"A" * 4096)
    v.write_file("/b", b"B" * (5 * 4096))   # 5 data blocks to reclaim
    v.fsync("/b")
    free0 = v.statfs()["free_blocks_est"]
    v.rename("/a", "/b")
    v.fsync("/b")
    assert v.statfs()["free_blocks_est"] == free0 + 5


def test_rename_onto_itself_is_noop(mounted):
    v = mounted.view
    v.write_file("/same", b"untouched")
    v.rename("/same", "/same")
    assert v.read_file("/same") == b"untouched"
    assert sorted(v.listdir("/")) == ["same"]


def test_rename_kind_mismatch_errnos(mounted):
    v = mounted.view
    v.mkdir("/d")
    v.write_file("/f", b"x")
    with pytest.raises(FsError) as ei:
        v.rename("/f", "/d")                 # file over dir
    assert ei.value.errno == Errno.EISDIR
    with pytest.raises(FsError) as ei:
        v.rename("/d", "/f")                 # dir over file
    assert ei.value.errno == Errno.ENOTDIR
    # nothing moved
    assert v.read_file("/f") == b"x"
    assert v.stat("/d").kind == FileKind.DIR


def test_rename_nonempty_dir_target_is_enotempty(mounted):
    v = mounted.view
    v.mkdir("/src")
    v.makedirs("/dst/child")
    with pytest.raises(FsError) as ei:
        v.rename("/src", "/dst")
    assert ei.value.errno == Errno.ENOTEMPTY
    assert v.exists("/src") and v.exists("/dst/child")


def test_rename_replaces_empty_dir_and_fixes_nlinks(mounted):
    v = mounted.view
    v.makedirs("/p/moved")
    v.mkdir("/q")
    v.mkdir("/q/gone")                       # the displaced empty dir
    root0 = v.stat("/").nlink
    v.rename("/p/moved", "/q/gone")
    assert v.stat("/q/gone").kind == FileKind.DIR
    assert not v.exists("/p/moved")
    assert v.stat("/p").nlink == 2           # lost its only child dir
    assert v.stat("/q").nlink == 3           # displaced -1, arrived +1
    assert v.stat("/").nlink == root0
    # the moved dir still works as a directory
    v.write_file("/q/gone/file", b"alive")
    assert v.read_file("/q/gone/file") == b"alive"


def test_rename_dir_across_parents_rehomes_nlink(mounted):
    v = mounted.view
    v.makedirs("/p/c")
    v.mkdir("/q")
    assert v.stat("/p").nlink == 3 and v.stat("/q").nlink == 2
    v.rename("/p/c", "/q/c")
    assert v.stat("/p").nlink == 2 and v.stat("/q").nlink == 3


def test_rename_into_own_subtree_is_einval(mounted):
    v = mounted.view
    v.makedirs("/s/t")
    with pytest.raises(FsError) as ei:
        v.rename("/s", "/s/t/cycle")
    assert ei.value.errno == Errno.EINVAL
    assert v.exists("/s/t")
    # the dir itself as the target parent is a cycle too
    with pytest.raises(FsError) as ei:
        v.rename("/s", "/s/inside")
    assert ei.value.errno == Errno.EINVAL


def test_rename_missing_source_and_bad_newname(mounted):
    v = mounted.view
    with pytest.raises(FsError) as ei:
        v.rename("/nope", "/x")
    assert ei.value.errno == Errno.ENOENT
    v.write_file("/ok", b"y")
    with pytest.raises(FsError) as ei:
        mounted.mount.call("rename", 1, "ok", 1, "bad/name")
    assert ei.value.errno == Errno.EINVAL
    assert v.read_file("/ok") == b"y"


def test_rename_overwrite_dcache_coherent(mounted):
    """The replaced name's dcache entry must not keep resolving to the
    displaced inode (PosixView invalidates it on rename)."""
    v = mounted.view
    v.write_file("/x", b"xx")
    v.write_file("/y", b"yy")
    ix = v.stat("/x").ino
    assert v.stat("/y").ino != ix            # warm the dcache with old y
    v.rename("/x", "/y")
    assert v.stat("/y").ino == ix            # re-resolved, not stale
    assert v.read_file("/y") == b"xx"


def test_rename_overwrite_in_batch_entry(mounted):
    """rename rides the batched boundary like any op: an overwrite inside
    a submission completes ok and neighbours are isolated."""
    from repro.core.interface import SubmissionEntry

    v = mounted.view
    v.write_file("/m1", b"one")
    v.write_file("/m2", b"two")
    comps = mounted.mount.submit([
        SubmissionEntry("rename", (1, "m1", 1, "m2"), user_data="r"),
        SubmissionEntry("lookup", (1, "m1"), user_data="gone"),
        SubmissionEntry("lookup", (1, "m2"), user_data="there"),
    ])
    by = {c.user_data: c for c in comps}
    assert by["r"].ok
    assert by["gone"].errno == Errno.ENOENT
    assert by["there"].ok
    # a raw batch bypasses the view's dcache invalidation — read via the
    # lookup completion's ino, the truth the boundary just returned
    assert mounted.mount.call("read", by["there"].result.ino, 0, 3) == b"one"


def test_ext4like_dirindex_survives_overwrite_rename():
    """The in-place slot rewrite must keep the live hash index coherent:
    lookups after the swap, plus creates reusing the directory, all agree
    with a cold re-scan."""
    mf = make_mount("ext4like", n_blocks=4096)
    v = mf.view
    v.makedirs("/d")
    for i in range(8):
        v.write_file(f"/d/f{i}", bytes([i]))
    v.rename("/d/f0", "/d/f7")               # overwrite inside one dir
    fs = mf.mount.module
    dino = v.stat("/d").ino
    idx = dict(fs._dirindex[dino])
    fs._dirindex.clear()                     # force a cold re-scan
    pdi = fs._iget(dino)
    assert fs._index(dino, pdi) == idx       # live index == disk truth
    assert v.read_file("/d/f7") == bytes([0])
    assert not v.exists("/d/f0")
    mf.close()
