"""Per-kernel shape/dtype sweeps: Pallas (interpret=True) vs the pure-jnp
ref.py oracle for every kernel."""

import os

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.kernels.blockhash import ops as bh_ops, ref as bh_ref
from repro.kernels.flash_attention import kernel as fa_k, ref as fa_ref
from repro.kernels.ssd import kernel as ssd_k, ref as ssd_ref
from repro.kernels.wkv6 import kernel as wkv_k, ref as wkv_ref


@pytest.mark.parametrize("B,Sq,Skv,Hq,Hkv,D,causal,window", [
    (2, 256, 256, 4, 2, 64, True, 0),
    (1, 512, 512, 8, 8, 128, True, 0),
    (2, 256, 256, 4, 4, 64, False, 0),
    (1, 512, 512, 4, 2, 64, True, 128),
    (1, 256, 512, 4, 1, 64, False, 0),  # cross-ish: Skv != Sq
])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention(B, Sq, Skv, Hq, Hkv, D, causal, window, dtype):
    ks = jax.random.split(jax.random.PRNGKey(0), 3)
    q = jax.random.normal(ks[0], (B, Sq, Hq, D), dtype)
    k = jax.random.normal(ks[1], (B, Skv, Hkv, D), dtype)
    v = jax.random.normal(ks[2], (B, Skv, Hkv, D), dtype)
    out = fa_k.flash_attention_fwd(q, k, v, causal=causal, window=window,
                                   interpret=True)
    want = fa_ref.attention(q, k, v, causal=causal, window=window)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(want, np.float32), atol=tol, rtol=tol)


def test_flash_attention_grad_matches_ref():
    from repro.kernels.flash_attention import ops as fa_ops
    ks = jax.random.split(jax.random.PRNGKey(1), 3)
    q = jax.random.normal(ks[0], (1, 128, 2, 64))
    k = jax.random.normal(ks[1], (1, 128, 2, 64))
    v = jax.random.normal(ks[2], (1, 128, 2, 64))

    def f_kernel(q, k, v):
        return jnp.sum(fa_ops.flash_attention(q, k, v, True, 0, 0.0, True) ** 2)

    def f_ref(q, k, v):
        return jnp.sum(fa_ref.attention(q, k, v, causal=True) ** 2)

    g1 = jax.grad(f_kernel)(q, k, v)
    g2 = jax.grad(f_ref)(q, k, v)
    np.testing.assert_allclose(np.asarray(g1), np.asarray(g2), atol=1e-3)


@pytest.mark.parametrize("B,S,H,K,V,C", [
    (2, 64, 3, 16, 16, 16),
    (1, 128, 2, 32, 32, 32),
    (1, 64, 1, 8, 8, 64),  # single chunk
])
def test_wkv6(B, S, H, K, V, C):
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    r = jax.random.normal(ks[0], (B, S, H, K)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, K)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, V))
    w = jax.random.normal(ks[3], (B, S, H, K)) * 0.3
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    s0 = jax.random.normal(ks[5], (B, H, K, V)) * 0.1
    y1, st1 = wkv_ref.wkv6(r, k, v, w, u, s0, chunk=C)
    y2, st2 = wkv_k.wkv6_chunked(r, k, v, w, u, s0, chunk=C, interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=1e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), atol=1e-4)


def test_wkv6_chunked_equals_stepwise():
    """Chunked scan == token-by-token recurrence (cross-oracle check)."""
    from repro.models.rwkv import wkv6_step
    B, S, H, K = 1, 32, 2, 8
    ks = jax.random.split(jax.random.PRNGKey(3), 5)
    r = jax.random.normal(ks[0], (B, S, H, K)) * 0.5
    k = jax.random.normal(ks[1], (B, S, H, K)) * 0.5
    v = jax.random.normal(ks[2], (B, S, H, K))
    w = jax.random.normal(ks[3], (B, S, H, K)) * 0.3
    u = jax.random.normal(ks[4], (H, K)) * 0.3
    s = jnp.zeros((B, H, K, K))
    y_chunk, s_chunk = wkv_ref.wkv6(r, k, v, w, u, s, chunk=8)
    ys = []
    st = s
    for t in range(S):
        y, st = wkv6_step(r[:, t], k[:, t], v[:, t], w[:, t], u, st)
        ys.append(y)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step), atol=1e-4)
    np.testing.assert_allclose(np.asarray(s_chunk), np.asarray(st), atol=1e-4)


@pytest.mark.parametrize("b,S,H,P,N,C", [
    (2, 128, 3, 16, 8, 32),
    (1, 256, 2, 64, 64, 128),
    (1, 64, 1, 8, 8, 64),
])
def test_ssd(b, S, H, P, N, C):
    ks = jax.random.split(jax.random.PRNGKey(0), 6)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    B = jax.random.normal(ks[2], (b, S, N)) * 0.5
    Cm = jax.random.normal(ks[3], (b, S, N)) * 0.5
    A_log = jax.random.normal(ks[4], (H,)) * 0.3
    D = jnp.ones((H,))
    h0 = jax.random.normal(ks[5], (b, H, P, N)) * 0.1
    y1, st1 = ssd_ref.ssd(x, dt, B, Cm, A_log, D, h0, chunk=C)
    y2, st2 = ssd_k.ssd_chunked(x, dt, B, Cm, A_log, D, h0, chunk=C,
                                interpret=True)
    np.testing.assert_allclose(np.asarray(y1), np.asarray(y2), atol=2e-4)
    np.testing.assert_allclose(np.asarray(st1), np.asarray(st2), atol=2e-4)


def test_ssd_chunked_equals_stepwise():
    from repro.models.mamba2 import ssd_step
    b, S, H, P, N = 1, 32, 2, 8, 4
    ks = jax.random.split(jax.random.PRNGKey(5), 6)
    x = jax.random.normal(ks[0], (b, S, H, P))
    dt = jax.nn.softplus(jax.random.normal(ks[1], (b, S, H)))
    B = jax.random.normal(ks[2], (b, S, N)) * 0.5
    C = jax.random.normal(ks[3], (b, S, N)) * 0.5
    A_log = jax.random.normal(ks[4], (H,)) * 0.3
    D = jnp.ones((H,))
    h = jnp.zeros((b, H, P, N))
    y_chunk, h_chunk = ssd_ref.ssd(x, dt, B, C, A_log, D, h, chunk=8)
    ys = []
    st = h
    for t in range(S):
        y, st = ssd_step(x[:, t], dt[:, t], B[:, t], C[:, t], A_log, D, st)
        ys.append(y)
    y_step = jnp.stack(ys, axis=1)
    np.testing.assert_allclose(np.asarray(y_chunk), np.asarray(y_step), atol=1e-4)
    np.testing.assert_allclose(np.asarray(h_chunk), np.asarray(st), atol=1e-4)


@pytest.mark.parametrize("nbytes", [16, 512, 4096, 4093])
def test_blockhash(nbytes):
    data = os.urandom(nbytes)
    assert bh_ops.checksum(data) == bh_ref.blockhash_np(data)


def test_blockhash_detects_corruption():
    data = bytearray(os.urandom(4096))
    h = bh_ops.checksum(bytes(data))
    data[100] ^= 0xFF
    assert bh_ops.checksum(bytes(data)) != h


def test_blockhash_batch():
    blocks = [os.urandom(4096) for _ in range(5)]
    got = bh_ops.checksum_batch(blocks)
    want = [bh_ref.blockhash_np(b) for b in blocks]
    assert got == want


def test_compiler_params_compat_shim():
    """One feature-detect for the whole kernel pack: every kernel uses the
    SAME class object from ``_compat``, and it constructs with the kwargs
    the kernels actually pass (a field rename breaks loudly here)."""
    from repro.kernels import _compat

    assert _compat.CompilerParams is not None
    for mod in (fa_k, wkv_k, ssd_k):
        assert mod._CompilerParams is _compat.CompilerParams
    from repro.kernels.blockhash import kernel as bh_k
    assert bh_k._CompilerParams is _compat.CompilerParams
    for sem in (("parallel",), ("parallel", "parallel", "arbitrary"),
                ("parallel", "parallel", "parallel", "arbitrary")):
        _compat.CompilerParams(dimension_semantics=sem)
