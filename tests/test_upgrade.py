"""Online upgrade (§4.8): state transfer under live mounts, version
migration, schema enforcement, and upgrade-under-concurrent-load."""

import threading
import time

import pytest

from repro.core.upgrade import UpgradeError, upgrade
from repro.fs.ext4like import Ext4LikeFileSystem
from repro.fs.mounts import make_mount
from repro.fs.xv6 import Xv6FileSystem, Xv6Options


def test_upgrade_preserves_data_and_pending_state():
    mf = make_mount("bento", n_blocks=4096)
    v = mf.view
    v.write_file("/pre", b"written before upgrade")
    # leave UNCOMMITTED journal state to prove in-memory transfer works
    assert len(mf.mount.module.journal._pending) >= 0
    gen0 = mf.mount.generation
    stats = upgrade(mf.mount, Xv6FileSystem(Xv6Options()))
    assert mf.mount.generation == gen0 + 1
    assert stats["total_s"] < 5.0
    assert v.read_file("/pre") == b"written before upgrade"
    v.write_file("/post", b"after")
    assert v.read_file("/post") == b"after"
    mf.close()


def test_upgrade_to_ext4like_migration():
    """Cross-module upgrade xv6 -> ext4like (same on-disk format, richer
    in-memory state): migrate hook fills the new dirindex field."""
    mf = make_mount("bento", n_blocks=4096)
    v = mf.view
    v.makedirs("/d")
    v.write_file("/d/f", b"x" * 5000)

    def migrate(state, old_v, new_v):
        state = dict(state)
        state.setdefault("dirindex", {})
        return state

    upgrade(mf.mount, Ext4LikeFileSystem(), migrate=migrate)
    assert v.read_file("/d/f") == b"x" * 5000
    v.write_file("/d/g", b"y")
    assert sorted(v.listdir("/d")) == ["f", "g"]
    mf.close()


def test_upgrade_schema_mismatch_rejected():
    class WeirdFs(Xv6FileSystem):
        VERSION = 9

        def state_schema(self):
            return ("icache", "free_hint", "free_inode_hint", "journal",
                    "stats", "quantum_flux")  # not provided by v1

    mf = make_mount("bento", n_blocks=4096)
    with pytest.raises(UpgradeError):
        upgrade(mf.mount, WeirdFs())
    # failed upgrade must leave the old module serving
    mf.view.write_file("/still_works", b"ok")
    assert mf.view.read_file("/still_works") == b"ok"
    mf.close()


def test_upgrade_under_concurrent_load_zero_failures():
    mf = make_mount("bento", n_blocks=8192)
    v = mf.view
    v.makedirs("/w")
    stop = threading.Event()
    errors = []

    def workload():
        i = 0
        while not stop.is_set():
            try:
                v.write_file(f"/w/f{i % 16}", b"z" * 2048)
                v.read_file(f"/w/f{i % 16}")
            except Exception as e:  # noqa: BLE001
                errors.append(e)
            i += 1

    t = threading.Thread(target=workload, daemon=True)
    t.start()
    time.sleep(0.2)
    for _ in range(3):
        upgrade(mf.mount, Xv6FileSystem(Xv6Options()))
        time.sleep(0.1)
    stop.set()
    t.join(5)
    assert not errors, f"ops failed during upgrade: {errors[:3]}"
    assert mf.mount.generation == 4
    mf.close()


def test_upgrade_during_chained_batch_never_interleaves_with_swap():
    """An in-flight chained submission racing an xv6→ext4like upgrade: the
    whole batch executes under one gate crossing, so the table swap can
    never land between two members of a chain — completions all come from
    one module generation, chain semantics (ECANCELED after a failed link)
    survive the race, and the upgraded module sees every created file."""
    import threading

    from repro.core.interface import (Errno, PrevResult, SQE_LINK,
                                      SubmissionEntry)

    mf = make_mount("bento", n_blocks=8192)
    v = mf.view
    v.makedirs("/d")
    dino = v.stat("/d").ino
    m = mf.mount
    gen0 = m.generation

    n_chains = 150
    entries = []
    for i in range(n_chains):
        # one poisoned chain mid-batch: duplicate name → EEXIST cancels its
        # write, with ECANCELED completions even while the upgrade races
        name = "dup" if i == 70 else f"f{i:04d}"
        entries.append(SubmissionEntry("create", (dino, name),
                                       user_data=(i, "c"), flags=SQE_LINK))
        entries.append(SubmissionEntry("write",
                                       (PrevResult("ino"), 0, b"x" * 64),
                                       user_data=(i, "w")))
    entries.insert(0, SubmissionEntry("create", (dino, "dup"),
                                      user_data=(-1, "c")))
    comps = []
    started = threading.Event()

    def submitter():
        started.set()
        comps.extend(m.submit(entries))

    t = threading.Thread(target=submitter, daemon=True)
    t.start()
    started.wait(5)

    def migrate(state, old_v, new_v):
        state = dict(state)
        state.setdefault("dirindex", {})
        return state

    upgrade(m, Ext4LikeFileSystem(), migrate=migrate)
    t.join(10)
    assert not t.is_alive()
    # exactly one swap; no lost/duplicated/reordered completions
    assert m.generation == gen0 + 1
    assert [c.user_data for c in comps] == \
        [e.user_data for e in entries]
    by_ud = {c.user_data: c for c in comps}
    assert by_ud[(70, "c")].errno == Errno.EEXIST
    assert by_ud[(70, "w")].errno == Errno.ECANCELED
    ok_chains = [i for i in range(n_chains) if i != 70]
    assert all(by_ud[(i, "c")].ok and by_ud[(i, "w")].result == 64
               for i in ok_chains)
    # the upgraded (ext4like) module serves every chain's file via its index
    assert isinstance(m.module, Ext4LikeFileSystem)
    for i in (0, 1, 70, 149):
        name = f"f{i:04d}"
        if i == 70:
            continue
        assert v.read_file(f"/d/{name}") == b"x" * 64
    assert len(v.listdir("/d")) == n_chains  # 149 chain files + dup
    mf.close()


def test_upgrade_during_recovery_window_preserves_chain_atomicity():
    """Crash a chained create→write→fsync mid-commit; power on straight
    into a *Bento mount* (init runs ``Journal.recover()``) and upgrade to
    ext4like before anything else touches the fs. The recovered state —
    whole chain or no chain — must survive the swap intact, and the
    upgraded module must keep serving."""
    from repro.core.registry import mount as bento_mount
    from repro.core.services import kernel_binding
    from repro.fs.crashsim import CrashSim, chain_workload
    from repro.fs.posix import PosixView

    payload = b"U" * (2 * 4096 + 7)
    sim = CrashSim(lambda: Xv6FileSystem(Xv6Options()))
    total = sim.measure(chain_workload(payload))

    def migrate(state, old_v, new_v):
        state = dict(state)
        state.setdefault("dirindex", {})
        return state

    # crash at several interesting windows: before, inside and after the
    # journal commit the fsync tail triggers
    for point in sorted({1, total // 2, total - 2, total}):
        rec = sim.run_one(chain_workload(payload), point, total=total)
        # remount the crashed+recovered device behind the REAL gate/table
        ks = kernel_binding(rec.dev, writeback="delayed")
        m = bento_mount("xv6", ks, module=Xv6FileSystem(Xv6Options()))
        v = PosixView(m)
        before = v.read_file("/f") if v.exists("/f") else None
        assert before in (None, payload), "half-applied chain pre-upgrade"

        upgrade(m, Ext4LikeFileSystem(), migrate=migrate)

        after = v.read_file("/f") if v.exists("/f") else None
        assert after == before, "upgrade changed recovered state"
        v.write_file("/post", b"serving after crash+recover+upgrade")
        assert v.read_file("/post") == b"serving after crash+recover+upgrade"
        m.unmount()


def test_trainer_module_state_transfer():
    from repro.configs import registry
    from repro.core.upgrade import transfer_state
    from repro.train.trainer import Trainer

    b = registry.get("smollm-135m")
    run = b.run.replace(microbatch_per_data_shard=0)
    t1 = Trainer(b.smoke, run, global_batch=2, seq_len=16)
    t1.train(3)
    t2 = Trainer(b.smoke, run, global_batch=2, seq_len=16, seed=99)
    transfer_state(t1, t2)
    assert t2.step_idx == 3
    m = t2.train(5)
    assert m["loss"] > 0
    # continuation must match t1 continuing directly
    m1 = t1.train(5)
    assert abs(m1["loss"] - m["loss"]) < 1e-4
