"""Pipeline parallelism: pipelined fwd/bwd == sequential reference.

Runs in a subprocess with 4 fake host devices (this process keeps 1).
"""

import os
import subprocess
import sys

import pytest

SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=4"
import jax, jax.numpy as jnp, numpy as np
from repro.distributed.pipeline import pipeline_apply, bubble_fraction

mesh = jax.make_mesh((4,), ("pod",),
                     axis_types=(jax.sharding.AxisType.Auto,))
S, L, M, mb, d = 4, 8, 6, 2, 16
rng = jax.random.PRNGKey(0)
ws = jax.random.normal(rng, (L, d, d)) * 0.2          # 8 layers
ws_stages = ws.reshape(S, L // S, d, d)                # 2 layers per stage
xs = jax.random.normal(jax.random.PRNGKey(1), (M, mb, d))

def stage_fn(w_stage, x):
    def body(x, w):
        return jnp.tanh(x @ w), None
    x, _ = jax.lax.scan(body, x, w_stage)
    return x

# sequential reference
def ref(ws, xs):
    def full(x):
        def body(x, w):
            return jnp.tanh(x @ w), None
        return jax.lax.scan(body, x, ws)[0]
    return jax.vmap(full)(xs)

want = ref(ws, xs)
got = pipeline_apply(stage_fn, ws_stages, xs, mesh, axis="pod")
np.testing.assert_allclose(np.asarray(got), np.asarray(want), atol=1e-5)
print("fwd ok")

# gradients through the pipeline == gradients through the reference
def loss_pipe(ws_stages):
    return jnp.sum(pipeline_apply(stage_fn, ws_stages, xs, mesh) ** 2)

def loss_ref(ws):
    return jnp.sum(ref(ws, xs) ** 2)

g_pipe = jax.grad(loss_pipe)(ws_stages).reshape(L, d, d)
g_ref = jax.grad(loss_ref)(ws)
np.testing.assert_allclose(np.asarray(g_pipe), np.asarray(g_ref), atol=1e-4)
print("bwd ok")
assert abs(bubble_fraction(4, 6) - 3/9) < 1e-9
print("PIPELINE_OK")
"""


@pytest.mark.slow
def test_pipeline_matches_sequential():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout[-1500:] + out.stderr[-1500:]
    assert "PIPELINE_OK" in out.stdout
