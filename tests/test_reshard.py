"""Topology-elastic checkpoints: the reshard planner (pure index math),
the v2 shard-per-file store format, v1 back-compat, and the streamed
reshard-on-restore path.

The planner tests are deviceless (ShardGrid + plan_target_shard against
direct numpy slicing, including uneven dims, empty cells, axis tuples and
scalars). The store tests round-trip virtual grids through a real bento
mount. The differential corpus — save on mesh A, restore onto the same,
a halved and a doubled mesh, byte-identical vs the whole-tensor
reference with bounded peak memory — runs in a subprocess with 8 fake
host devices (this process keeps 1)."""

import io
import json
import os
import subprocess
import sys

import numpy as np
import pytest

from repro.distributed.resharding import (
    ReadOp, ShardGrid, chunk_ops, index_volume, normalize_index, op_bytes,
    plan_reshard, plan_target_shard, plan_volume, shift_ops,
)
from repro.fs.mounts import make_mount


# --- planner: grids, normalization, manifest round-trip ---------------------


def test_normalize_index_fills_open_slices():
    got = normalize_index((slice(2, 6), slice(None)), (8, 10))
    assert got == ((2, 6), (0, 10))
    assert normalize_index((), ()) == ()


def test_shard_grid_from_spec_shapes():
    g = ShardGrid.from_spec((8, 8), ("d", "m"), {"d": 2, "m": 2})
    assert g.grid == (2, 2) and g.n_shards == 4
    assert g.index(0) == ((0, 4), (0, 4))
    assert g.index(3) == ((4, 8), (4, 8))
    # axis tuple: one dim cut d*m ways
    g = ShardGrid.from_spec((12,), (("d", "m"),), {"d": 2, "m": 3})
    assert g.grid == (6,)
    assert g.indices()[0] == ((0, 2),)
    # trailing None implied; replicated dim uncut
    g = ShardGrid.from_spec((4, 6), ("d",), {"d": 4})
    assert g.grid == (4, 1)
    # scalar
    g = ShardGrid.trivial(())
    assert g.n_shards == 1 and g.index(0) == ()


def test_shard_grid_uneven_dims_use_ceil_div():
    g = ShardGrid.from_spec((5,), ("d",), {"d": 4})
    # ceil(5/4)=2 -> cells (0,2),(2,4),(4,5),(5,5): last one EMPTY
    assert g.indices() == [((0, 2),), ((2, 4),), ((4, 5),), ((5, 5),)]


def test_shard_grid_manifest_round_trip():
    g = ShardGrid.from_spec((4, 6, 8), (None, "m", ("d", "m")),
                            {"d": 2, "m": 3})
    rec = g.to_manifest()
    back = ShardGrid.from_manifest((4, 6, 8), json.loads(json.dumps(rec)))
    assert back == g
    assert back.indices() == g.indices()


GRID_CASES = [
    ((8, 8), ("d", "m"), {"d": 2, "m": 2}),
    ((7, 5), ("d", None), {"d": 3}),              # uneven
    ((16,), (("d", "m"),), {"d": 2, "m": 3}),     # axis tuple
    ((4, 6, 8), (None, "m", "d"), {"d": 4, "m": 2}),
    ((5, 3), ("d", "m"), {"d": 4, "m": 2}),       # uneven + empty cells
    ((9,), ("d",), {"d": 1}),                     # single-cell grid
]


@pytest.mark.parametrize("shape,spec,axes", GRID_CASES)
def test_grid_cells_tile_the_shape_exactly(shape, spec, axes):
    g = ShardGrid.from_spec(shape, spec, axes)
    counts = np.zeros(shape, dtype=np.int32)
    for idx in g.indices():
        counts[tuple(slice(lo, hi) for lo, hi in idx)] += 1
    assert (counts == 1).all(), "grid cells overlap or leave holes"


@pytest.mark.parametrize("src_case", GRID_CASES)
@pytest.mark.parametrize("dst_axes", [{"x": 2}, {"x": 3, "y": 2}])
def test_plan_matches_direct_slicing(src_case, dst_axes):
    """For every (source grid, target grid) pair over the same shape:
    the plan covers each target cell exactly once and executing it
    against the source shard arrays reproduces direct slicing of the
    full tensor."""
    shape, spec, axes = src_case
    src = ShardGrid.from_spec(shape, spec, axes)
    names = list(dst_axes)
    dst_spec = tuple(names[i % len(names)] if i % 2 == 0 else None
                     for i in range(len(shape)))
    dst = ShardGrid.from_spec(shape, dst_spec, dst_axes)
    full = np.arange(int(np.prod(shape, dtype=np.int64)) or 1,
                     dtype=np.int64).reshape(shape)
    shards = [full[tuple(slice(lo, hi) for lo, hi in idx)]
              for idx in src.indices()]
    plans = plan_reshard(src.indices(), dst)
    for t, ops in enumerate(plans):
        di = dst.index(t)
        if index_volume(di) == 0:
            assert plan_volume(ops) == 0
            continue
        assert plan_volume(ops) == index_volume(di)  # exact cover
        buf = np.full(tuple(hi - lo for lo, hi in di), -1, dtype=np.int64)
        cover = np.zeros_like(buf, dtype=np.int32)
        for op in ops:
            d = tuple(slice(lo, hi) for lo, hi in op.dst_slice)
            s = tuple(slice(lo, hi) for lo, hi in op.src_slice)
            buf[d] = shards[op.src][s]
            cover[d] += 1
        assert (cover == 1).all(), "ops overlap or leave holes"
        np.testing.assert_array_equal(
            buf, full[tuple(slice(lo, hi) for lo, hi in di)])


def test_plan_scalar_overlaps_every_source():
    ops = plan_target_shard([()], ())
    assert ops == [ReadOp(0, (), ())]
    assert plan_volume(ops) == index_volume(()) == 1


def test_plan_skips_disjoint_sources():
    src = [((0, 4),), ((4, 8),)]
    ops = plan_target_shard(src, ((0, 4),))
    assert [op.src for op in ops] == [0]
    ops = plan_target_shard(src, ((2, 6),))
    assert [(op.src, op.src_slice, op.dst_slice) for op in ops] == \
        [(0, ((2, 4),), ((0, 2),)), (1, ((0, 2),), ((2, 4),))]


# --- the v2 store on a real mount: virtual grids, no devices needed ---------


def _virtual_tree():
    import jax.numpy as jnp
    rng = np.random.default_rng(11)
    return {
        "w": jnp.asarray(rng.normal(size=(8, 6)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(12,)).astype(np.float32)
                         ).astype(jnp.bfloat16),
        "s": jnp.float32(2.5),
    }


def _virtual_grids():
    return {
        "w": ShardGrid.from_spec((8, 6), ("d", "m"), {"d": 2, "m": 3}),
        "b": ShardGrid.from_spec((12,), ("d",), {"d": 2}),
        "s": None,
    }


def test_v2_sharded_save_round_trips_and_streams():
    import jax
    from repro import checkpoint as ckpt

    mf = make_mount("bento", n_blocks=16384)
    cks = mf.services.checksum
    tree, grids = _virtual_tree(), _virtual_grids()
    man = ckpt.save(mf.view, "/ck/s1", tree, step=1, checksum=cks,
                    shardings=grids)
    assert man["version"] == 2
    by_leaf = {i: rec for i, rec in enumerate(man["leaves"])}
    # dict pytree flattens in sorted key order: b, s, w
    assert len(by_leaf[0]["shards"]) == 2          # b: 2 shards
    assert len(by_leaf[1]["shards"]) == 1          # s: scalar
    assert len(by_leaf[2]["shards"]) == 6          # w: 2x3 grid
    names = sorted(n for n in mf.view.listdir("/ck/s1")
                   if n.startswith("leaf_"))
    assert names[0] == "leaf_00000_s000.npy" and len(names) == 9
    stats = {}
    back, _ = ckpt.load(mf.view, "/ck/s1", tree, checksum=cks, stats=stats)
    for k in tree:
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(back[k])).view(np.uint16)
            if k == "b" else np.asarray(jax.device_get(back[k])),
            np.asarray(jax.device_get(tree[k])).view(np.uint16)
            if k == "b" else np.asarray(jax.device_get(tree[k])))
        assert back[k].dtype == tree[k].dtype
    assert stats["version"] == 2
    streamed = [s for s in stats["leaves"] if s["streamed"]]
    assert {s["n_src_shards"] for s in streamed} == {2, 6}
    mf.close()


def test_v2_resave_keeps_generation_discipline():
    """Re-saving a SHARDED checkpoint bumps the generation on every shard
    name, swaps atomically, and collects the whole prior generation."""
    from repro import checkpoint as ckpt

    mf = make_mount("bento", n_blocks=16384)
    cks = mf.services.checksum
    tree, grids = _virtual_tree(), _virtual_grids()
    ckpt.save(mf.view, "/ck/s", tree, step=0, checksum=cks, shardings=grids)
    man = ckpt.save(mf.view, "/ck/s", tree, step=0, checksum=cks,
                    shardings=grids)
    assert man["gen"] == 1
    names = sorted(n for n in mf.view.listdir("/ck/s")
                   if n.startswith("leaf_"))
    assert len(names) == 9 and all(n.endswith("_g1.npy") for n in names)
    back, _ = ckpt.load(mf.view, "/ck/s", tree, checksum=cks)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))
    mf.close()


def test_corrupted_shard_names_exact_file():
    """A flipped byte in ONE shard of a multi-shard leaf surfaces as an
    IOError naming that precise shard path (the verify pass runs before
    any assembly buffer exists)."""
    from repro import checkpoint as ckpt

    mf = make_mount("bento", n_blocks=16384)
    cks = mf.services.checksum
    tree, grids = _virtual_tree(), _virtual_grids()
    man = ckpt.save(mf.view, "/ck/s1", tree, step=1, checksum=cks,
                    shardings=grids)
    victim = man["leaves"][2]["shards"][3]["path"]
    raw = bytearray(mf.view.read_file(victim))
    raw[-1] ^= 0xFF
    mf.view.write_file(victim, bytes(raw), off=0, create=False)
    with pytest.raises(IOError, match=victim.replace(".", r"\.")):
        ckpt.load(mf.view, "/ck/s1", tree, checksum=cks)
    mf.close()


def test_missing_shard_record_fails_as_incomplete():
    """A manifest whose shard records no longer tile a leaf (a hand-edited
    or torn record set) must fail coverage-checked, not assemble garbage."""
    from repro import checkpoint as ckpt

    mf = make_mount("bento", n_blocks=16384)
    tree, grids = _virtual_tree(), _virtual_grids()
    ckpt.save(mf.view, "/ck/s1", tree, step=1, shardings=grids)
    man = json.loads(mf.view.read_file("/ck/s1/manifest.json"))
    dropped = man["leaves"][2]["shards"].pop()
    raw = json.dumps(man).encode()
    old_len = mf.view.stat("/ck/s1/manifest.json").size
    mf.view.write_file("/ck/s1/manifest.json",
                       raw + b" " * (old_len - len(raw)), off=0,
                       create=False)
    with pytest.raises(IOError, match="incomplete checkpoint"):
        ckpt.load(mf.view, "/ck/s1", tree)
    assert dropped["path"]  # the record really came off a multi-shard leaf
    mf.close()


def test_streamed_restore_without_data_off_falls_back_whole_file():
    """Shard records missing ``data_off`` (hand-written manifests) load
    via whole-file reads through the same plan."""
    from repro import checkpoint as ckpt

    mf = make_mount("bento", n_blocks=16384)
    tree, grids = _virtual_tree(), _virtual_grids()
    ckpt.save(mf.view, "/ck/s1", tree, step=1, shardings=grids)
    man = json.loads(mf.view.read_file("/ck/s1/manifest.json"))
    old_len = mf.view.stat("/ck/s1/manifest.json").size
    for rec in man["leaves"]:
        for s in rec["shards"]:
            s.pop("data_off", None)
    raw = json.dumps(man).encode()
    mf.view.write_file("/ck/s1/manifest.json",
                       raw + b" " * (old_len - len(raw)), off=0,
                       create=False)
    back, _ = ckpt.load(mf.view, "/ck/s1", tree)
    np.testing.assert_array_equal(np.asarray(back["w"]),
                                  np.asarray(tree["w"]))
    mf.close()


# --- v1 back-compat: whole-leaf manifests keep loading ----------------------


def test_v1_manifest_loads_through_v2_machinery():
    """A hand-written v1 checkpoint (whole-leaf files, per-leaf ``path``
    records, no ``version``) restores through the same load path as a
    1-shard grid — including a bf16 leaf stored as its uint16 wire view."""
    import jax.numpy as jnp
    import ml_dtypes
    from repro import checkpoint as ckpt

    mf = make_mount("bento", n_blocks=16384)
    cks = mf.services.checksum
    w = np.arange(24, dtype=np.float32).reshape(4, 6)
    b = np.arange(5, dtype=np.float32).astype(ml_dtypes.bfloat16)
    mf.view.makedirs("/ck/step_1")
    leaves, raws = [], []
    for i, (arr, dtype_s) in enumerate([(b, "bfloat16"), (w, "float32")]):
        buf = io.BytesIO()
        np.save(buf, arr.view(np.uint16) if dtype_s == "bfloat16" else arr)
        raw = buf.getvalue()
        path = f"/ck/step_1/leaf_{i:05d}.npy"
        mf.view.write_file(path, raw)
        leaves.append({"path": path, "shape": list(arr.shape),
                       "dtype": dtype_s, "checksum": cks(raw)})
        raws.append(raw)
    like = {"b": jnp.zeros((5,), jnp.bfloat16), "w": jnp.zeros((4, 6))}
    import jax
    treedef = jax.tree.flatten(like)[1]
    manifest = {"step": 1, "gen": 0, "treedef": str(treedef),
                "n_leaves": 2, "leaves": leaves, "extra": {}}
    mf.view.write_file("/ck/step_1/manifest.json",
                       json.dumps(manifest).encode())
    stats = {}
    back, man = ckpt.load(mf.view, "/ck/step_1", like, checksum=cks,
                          stats=stats)
    assert stats["version"] == 1
    np.testing.assert_array_equal(np.asarray(back["w"]), w)
    np.testing.assert_array_equal(
        np.asarray(back["b"]).view(np.uint16), b.view(np.uint16))
    assert ckpt.latest_step(mf.view, "/ck") == 1
    # a v2 re-save over the v1 checkpoint probes past the v1 names
    man2 = ckpt.save(mf.view, "/ck/step_1", like, step=1, checksum=cks)
    assert man2["gen"] >= 1 and man2["version"] == 2
    mf.close()


# --- load validation: incompatible trees fail loudly ------------------------


def test_load_rejects_wrong_treedef():
    from repro import checkpoint as ckpt

    mf = make_mount("bento", n_blocks=16384)
    tree = {"a": np.zeros(3, np.float32), "b": np.ones(3, np.float32)}
    ckpt.save(mf.view, "/ck/s", tree, step=0)
    wrong = {"a": np.zeros(3, np.float32), "c": np.ones(3, np.float32)}
    with pytest.raises(ValueError, match="tree structure does not match"):
        ckpt.load(mf.view, "/ck/s", wrong)
    mf.close()


def test_load_rejects_dtype_mismatch_naming_first_bad_leaf():
    from repro import checkpoint as ckpt

    mf = make_mount("bento", n_blocks=16384)
    tree = {"a": np.zeros(3, np.float32), "b": np.ones(4, np.float32)}
    ckpt.save(mf.view, "/ck/s", tree, step=0)
    like = {"a": np.zeros(3, np.float32), "b": np.ones(4, np.int32)}
    with pytest.raises(ValueError,
                       match=r"leaf 1 \(leaf_00001_s000\.npy\).*float32"):
        ckpt.load(mf.view, "/ck/s", like)
    mf.close()


def test_load_rejects_shape_mismatch():
    from repro import checkpoint as ckpt

    mf = make_mount("bento", n_blocks=16384)
    ckpt.save(mf.view, "/ck/s", {"a": np.zeros((3, 2), np.float32)}, step=0)
    with pytest.raises(ValueError, match=r"leaf 0 .*shape"):
        ckpt.load(mf.view, "/ck/s", {"a": np.zeros((2, 3), np.float32)})
    mf.close()


def test_load_rejects_leaf_count_mismatch():
    from repro import checkpoint as ckpt

    mf = make_mount("bento", n_blocks=16384)
    ckpt.save(mf.view, "/ck/s", {"a": np.zeros(3, np.float32)}, step=0)
    with pytest.raises(ValueError, match="incompatible trees"):
        ckpt.load(mf.view, "/ck/s",
                  {"a": np.zeros(3, np.float32), "b": np.zeros(1)})
    mf.close()


# --- the differential corpus: mesh A -> {A, halved, doubled} ----------------

RESHARD_SCRIPT = r"""
import os
os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
import jax, jax.numpy as jnp, numpy as np
from jax.sharding import NamedSharding, PartitionSpec as P
from repro import checkpoint as ckpt
from repro.fs.mounts import make_mount
from repro.launch.mesh import make_elastic_mesh

SPECS = {"w": P("data", "model"), "e": P("model", None),
         "b": P("data"), "r": P(), "s": P()}
rng = np.random.default_rng(3)
host = {"w": rng.normal(size=(64, 32)).astype(np.float32),
        "e": rng.normal(size=(32, 16)).astype(np.float32),
        "b": rng.normal(size=(256,)).astype(np.float32),
        "r": rng.normal(size=(8, 8)).astype(np.float32),
        "s": np.float32(3.5)}

mesh_a = make_elastic_mesh(2, 2)
sh_a = {k: NamedSharding(mesh_a, SPECS[k]) for k in host}
tree = {k: jax.device_put(jnp.asarray(v), sh_a[k]) for k, v in host.items()}

mf = make_mount("bento", n_blocks=16384)
cks = mf.services.checksum
man = ckpt.save(mf.view, "/ck/s1", tree, step=1, checksum=cks,
                shardings=sh_a)
assert man["version"] == 2
n_shards = {i: len(r["shards"]) for i, r in enumerate(man["leaves"])}
# sorted keys: b, e, r, s, w -> data-sharded b:2, model e:2, repl r/s:1, w:4
assert n_shards == {0: 2, 1: 2, 2: 1, 3: 1, 4: 4}, n_shards

like = {k: jnp.zeros(host[k].shape, host[k].dtype) for k in host}
for name, (d, m) in (("same", (2, 2)), ("halved", (1, 2)),
                     ("doubled", (4, 2))):
    mesh_b = make_elastic_mesh(d, m)
    sh_b = {k: NamedSharding(mesh_b, SPECS[k]) for k in host}
    stats = {}
    back, _ = ckpt.load(mf.view, "/ck/s1", like, checksum=cks,
                        sharding_tree=sh_b, stats=stats)
    for k in host:  # byte-identical vs the whole-tensor reference
        np.testing.assert_array_equal(
            np.asarray(jax.device_get(back[k])), host[k])
        got = back[k].sharding.devices_indices_map(host[k].shape)
        want = sh_b[k].devices_indices_map(host[k].shape)
        assert got == want, (name, k)
    # bounded peak: any leaf whose target shards are proper subsets must
    # assemble strictly below full-tensor bytes
    strict = 0
    for ls in stats["leaves"]:
        if ls["streamed"] and ls["max_target_bytes"] < ls["full_bytes"]:
            assert ls["peak_bytes"] < ls["full_bytes"], (name, ls)
            strict += 1
    assert strict >= 2, (name, stats["leaves"])
    print(name, "ok")
print("RESHARD_OK")
"""


def test_reshard_differential_same_halved_doubled_meshes():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.abspath(
        os.path.join(os.path.dirname(__file__), "..", "src"))
    out = subprocess.run([sys.executable, "-c", RESHARD_SCRIPT], env=env,
                         capture_output=True, text=True, timeout=600)
    assert out.returncode == 0, out.stdout[-1500:] + out.stderr[-1500:]
    assert "RESHARD_OK" in out.stdout


# --- chunk scheduling: the overlap engine's planner math --------------------


def test_shift_ops_rebases_cell_local_dst_to_global():
    src = [((0, 4), (0, 6)), ((4, 8), (0, 6))]
    cell = ((2, 6), (0, 3))
    ops = plan_target_shard(src, cell)
    shifted = shift_ops(ops, cell)
    assert [op.dst_slice for op in shifted] == \
        [((2, 4), (0, 3)), ((4, 6), (0, 3))]
    # sources untouched, volume preserved
    assert [(op.src, op.src_slice) for op in shifted] == \
        [(op.src, op.src_slice) for op in ops]
    assert plan_volume(shifted) == plan_volume(ops) == index_volume(cell)


def test_shift_ops_short_last_cell_lands_flush():
    # uneven 8/3 target: the short last cell (6,8) shifts to the tail
    dst = ShardGrid.from_spec((8,), ("d",), {"d": 3})
    src = ShardGrid.from_spec((8,), ("d",), {"d": 2}).indices()
    last = dst.index(2)
    assert last == ((6, 8),)
    ops = plan_target_shard(src, last)
    assert ops == [ReadOp(1, ((2, 4),), ((0, 2),))]
    assert shift_ops(ops, last) == [ReadOp(1, ((2, 4),), ((6, 8),))]


def test_chunk_ops_budget_packing_preserves_order():
    src = ShardGrid.from_spec((64,), ("d",), {"d": 8}).indices()
    ops = plan_target_shard(src, ((0, 64),))  # 8 ops x 32B at itemsize 4
    chunks = chunk_ops(ops, 4, 64)
    assert [op for c in chunks for op in c] == ops  # concatenation == plan
    assert [len(c) for c in chunks] == [2, 2, 2, 2]
    assert all(sum(op_bytes(o, 4) for o in c) <= 64 for c in chunks)
    # an op bigger than the whole budget travels alone
    assert all(len(c) == 1 for c in chunk_ops(ops, 4, 16))
    # max_ops caps chunk length even under an unbounded byte budget
    assert [len(c) for c in chunk_ops(ops, 4, 1 << 30, max_ops=3)] == \
        [3, 3, 2]
    assert chunk_ops([], 4, 64) == []


# --- uneven (non-divisible) target grids: A -> uneven-B byte-identity -------


def test_uneven_target_grids_restore_byte_identical_at_all_depths():
    """Target ShardGrids jax's NamedSharding would refuse (short last
    cell on w, EMPTY last cell on b) restore byte-identically at every
    pipeline depth. Such a leaf assembles into one full-shape host
    buffer, so ``max_target_bytes == full_bytes`` marks it exempt from
    the strict sub-full peak budget."""
    import jax
    from repro import checkpoint as ckpt

    mf = make_mount("bento", n_blocks=16384)
    cks = mf.services.checksum
    tree, grids = _virtual_tree(), _virtual_grids()
    ckpt.save(mf.view, "/ck/u", tree, step=1, checksum=cks, shardings=grids)
    targets = {
        "w": ShardGrid.from_spec((8, 6), ("d", None), {"d": 3}),  # 3,3,2
        "b": ShardGrid.from_spec((12,), ("d",), {"d": 5}),  # 3,3,3,3,<empty>
        "s": ShardGrid.trivial(()),
    }
    for depth in (0, 1, 2, 4):
        stats = {}
        back, _ = ckpt.load(mf.view, "/ck/u", tree, checksum=cks,
                            sharding_tree=targets, stats=stats,
                            pipeline_depth=depth)
        for k in tree:
            np.testing.assert_array_equal(
                np.asarray(jax.device_get(back[k])).view(np.uint16)
                if k == "b" else np.asarray(jax.device_get(back[k])),
                np.asarray(jax.device_get(tree[k])).view(np.uint16)
                if k == "b" else np.asarray(jax.device_get(tree[k])))
            assert back[k].dtype == tree[k].dtype
        # sorted leaves: b, s, w — empty cells drop out of target groups
        by_leaf = {s["leaf"]: s for s in stats["leaves"]}
        assert by_leaf[0]["n_target_groups"] == 4, (depth, by_leaf)
        assert by_leaf[2]["n_target_groups"] == 3, (depth, by_leaf)
        for i in (0, 2):
            assert by_leaf[i]["max_target_bytes"] == \
                by_leaf[i]["full_bytes"], (depth, by_leaf[i])
    mf.close()


# --- overlap-on/off differential: pipelined vs serial reference -------------


def _overlap_tree():
    import jax.numpy as jnp
    rng = np.random.default_rng(7)
    return {
        "w": jnp.asarray(rng.normal(size=(64, 48)).astype(np.float32)),
        "b": jnp.asarray(rng.normal(size=(4096,)).astype(np.float32)),
        "s": jnp.float32(1.25),
    }


def _overlap_grids():
    return {
        "w": ShardGrid.from_spec((64, 48), ("d", "m"), {"d": 2, "m": 3}),
        "b": ShardGrid.from_spec((4096,), ("d",), {"d": 4}),
        "s": None,
    }


def test_overlap_differential_depths_match_serial_with_bounded_peak():
    """Pipelined restores (depths 1/2/4) are byte-identical to the serial
    reference (depth 0), the per-leaf metered peak stays within depth x
    the serial peak, and ``stats['pipeline']`` reports the engine."""
    import jax
    from repro import checkpoint as ckpt

    mf = make_mount("bento", n_blocks=16384)
    cks, cks_b = mf.services.checksum, mf.services.checksum_batch
    tree, grids = _overlap_tree(), _overlap_grids()
    ckpt.save(mf.view, "/ck/ov", tree, step=1, checksum=cks,
              shardings=grids)
    ref_stats = {}
    ref, _ = ckpt.load(mf.view, "/ck/ov", tree, checksum=cks,
                       stats=ref_stats, pipeline_depth=0)
    assert ref_stats["pipeline"]["depth"] == 0
    serial_peak = {s["leaf"]: s["peak_bytes"] for s in ref_stats["leaves"]}
    for depth in (1, 2, 4):
        stats = {}
        back, _ = ckpt.load(mf.view, "/ck/ov", tree, checksum=cks,
                            checksum_batch=cks_b, stats=stats,
                            pipeline_depth=depth)
        for k in tree:
            np.testing.assert_array_equal(
                np.asarray(jax.device_get(back[k])),
                np.asarray(jax.device_get(ref[k])))
        pipe = stats["pipeline"]
        assert pipe["depth"] == depth
        assert pipe["wall_s"] > 0.0 and pipe["overlap_ratio"] >= 0.0
        # the peak budget grows by exactly the configured depth, not more
        for s in stats["leaves"]:
            if s["streamed"]:
                cap = max(1, depth) * serial_peak[s["leaf"]]
                assert s["peak_bytes"] <= cap, (depth, s, cap)
    mf.close()


def test_pipeline_depth_env_default(monkeypatch):
    from repro.checkpoint import store as st

    monkeypatch.setenv(st._DEPTH_ENV, "3")
    assert st._resolve_depth(None) == 3
    assert st._resolve_depth(0) == 0  # explicit arg beats the env
    monkeypatch.delenv(st._DEPTH_ENV)
    assert st._resolve_depth(None) == st._DEFAULT_DEPTH
    assert st._resolve_depth(-5) == 0  # clamped


def test_crash_mid_restore_leaves_store_read_only(monkeypatch):
    """A checksum failure mid-pipelined-restore — speculative prefetches
    in flight — surfaces as the same IOError as the serial engine and
    performs ZERO device writes: the store is untouched and a restart
    can retry or fall back to an older step."""
    from repro import checkpoint as ckpt
    from repro.checkpoint import store as store_mod

    # the fixture tree is tiny — force the worker-thread engine anyway,
    # it is exactly the speculative-prefetch path under test
    monkeypatch.setattr(store_mod, "_INLINE_BYTES", 0)
    mf = make_mount("bento", n_blocks=16384)
    cks, cks_b = mf.services.checksum, mf.services.checksum_batch
    tree, grids = _virtual_tree(), _virtual_grids()
    man = ckpt.save(mf.view, "/ck/cr", tree, step=1, checksum=cks,
                    shardings=grids)
    victim = man["leaves"][2]["shards"][0]["path"]
    raw = bytearray(mf.view.read_file(victim))
    raw[-1] ^= 0xFF
    mf.view.write_file(victim, bytes(raw), off=0, create=False)
    mf.view.fsync(victim)  # corruption durable BEFORE the write snapshot
    w0 = mf.dev.writes
    for depth in (0, 2, 4):
        with pytest.raises(IOError, match="checksum mismatch|/ck/cr"):
            ckpt.load(mf.view, "/ck/cr", tree, checksum=cks,
                      checksum_batch=cks_b, pipeline_depth=depth)
    assert mf.dev.writes == w0, "restore wrote to the device"
    mf.close()
