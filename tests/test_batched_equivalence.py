"""Differential equivalence harness (the headline test of the chained-SQE
PR): the SAME operation sequence executed (a) scalar — one dispatch per op
on a twin mount — and (b) batched/chained — grouped into submissions with
random SQE_LINK flags — must produce byte-identical filesystem state and
identical per-entry errno vectors.

The scalar reference implements the documented chain rule by hand (stop at
the first failing link, remaining members ECANCELED, PrevResult fed from
the reference's own completions), so any divergence in the vectorized
fast paths (create_many / unlink_many / lookup_many / read_many /
write_many, the run coalescing in submit_batch, or the chain executor)
shows up as a failed comparison, not a plausible-looking pass.

Runs everywhere: a deterministic corpus (seeded random.Random sequences +
handcrafted edge cases) always executes; when hypothesis is available a
property-based version explores further.
"""

from __future__ import annotations

import random
from typing import Dict, List, Optional, Tuple

import pytest

from repro.core.interface import (Attr, Errno, FsError, PrevResult, ROOT_INO,
                                  SQE_DRAIN, SQE_LINK, SubmissionEntry)
from repro.fs.mounts import make_mount

try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:  # deterministic corpus still runs
    hp = None
    st = None


# --- op-sequence model ----------------------------------------------------------
#
# A step is (op, args, link) where args name inodes through a small fixed
# namespace set up identically on both twins, so inos match across mounts:
#   * dirs: ROOT + /d0 /d1 (created in setup, inos recorded)
#   * pre-made files: /d0/p0 /d0/p1 (data ops may target them by ino)
#   * names: a small pool, so create/unlink/lookup collide often (EEXIST,
#     ENOENT, chain cancellations)
# Chained create→write pairs use PrevResult("ino"), exercising placeholder
# substitution on both sides.

NAMES = ["a", "b", "c", "dd", "ee"]


def _setup(kind: str):
    mf = make_mount(kind, n_blocks=4096)
    v = mf.view
    v.makedirs("/d0")
    v.makedirs("/d1")
    v.write_file("/d0/p0", b"seed-zero" * 40)
    v.write_file("/d0/p1", b"seed-one" * 40)
    dirs = [ROOT_INO, v.stat("/d0").ino, v.stat("/d1").ino]
    files = [v.stat("/d0/p0").ino, v.stat("/d0/p1").ino]
    return mf, dirs, files


def gen_steps(rng: random.Random, n: int) -> List[Tuple]:
    """A deterministic pseudo-random op sequence over the twin namespace.

    Emitted tuples: (op, argspec, link) — argspec indexes the namespace
    (dirs by position, files by position) so both twins build identical
    concrete args."""
    steps: List[Tuple] = []
    i = 0
    while i < n:
        r = rng.random()
        d = rng.randrange(3)
        name = rng.choice(NAMES)
        if r < 0.18:
            steps.append(("create", (d, name), rng.random() < 0.3))
        elif r < 0.30:
            steps.append(("unlink", (d, name), rng.random() < 0.3))
        elif r < 0.38:
            steps.append(("mkdir", (d, name), False))
        elif r < 0.50:
            steps.append(("lookup", (d, name), rng.random() < 0.3))
        elif r < 0.62:
            f = rng.randrange(2)
            steps.append(("write", (f, rng.randrange(3) * 100,
                                    bytes([65 + rng.randrange(26)])
                                    * rng.randrange(1, 200)),
                          rng.random() < 0.3))
        elif r < 0.74:
            f = rng.randrange(2)
            steps.append(("read", (f, rng.randrange(3) * 100,
                                   rng.randrange(1, 300)),
                          rng.random() < 0.3))
        elif r < 0.80:
            steps.append(("getattr_dir", (d,), False))
        elif r < 0.86:
            steps.append(("readdir", (d,), False))
        elif r < 0.93:
            # chained create→write pair: write consumes PrevResult("ino")
            steps.append(("chain_cw", (d, name,
                                       bytes([97 + rng.randrange(26)])
                                       * rng.randrange(1, 150)), None))
            i += 1  # counts as two entries
        else:
            steps.append(("fsync", (0,), False))
        i += 1
    return steps


def gen_deep_chain_steps(rng: random.Random, n_chains: int) -> List[Tuple]:
    """Chains whose journal footprint exceeds one MAXOP_BLOCKS (16)
    reservation — multi-block single writes, deep linked write runs, and
    big PrevResult create→write pairs — sized to always FIT the journal
    (capacity 63 on these mounts), so the chain-transaction path executes
    rather than refusing with ENOSPC (refusal is unit-tested separately:
    the scalar reference cannot emulate it)."""
    steps: List[Tuple] = []
    for _ in range(n_chains):
        r = rng.random()
        f = rng.randrange(2)
        if r < 0.4:
            # one write > MAXOP_BLOCKS: 17-24 blocks
            nb = rng.randrange(17, 25)
            steps.append(("write", (f, rng.randrange(2) * L_BSIZE,
                                    bytes([65 + rng.randrange(26)])
                                    * (nb * L_BSIZE)), True))
            steps.append(("fsync", (f,), False))
        elif r < 0.75:
            # deep chain: 4-6 linked writes of 2-4 blocks each
            depth = rng.randrange(4, 7)
            for k in range(depth):
                nb = rng.randrange(2, 5)
                steps.append(("write", (f, k * 4 * L_BSIZE,
                                        bytes([97 + rng.randrange(26)])
                                        * (nb * L_BSIZE)), True))
            steps.append(("fsync", (f,), False))
        else:
            # chained create→write(PrevResult) with a >MAXOP payload;
            # name collisions exercise mid-chain cancellation too
            steps.append(("chain_cw", (rng.randrange(3), rng.choice(NAMES),
                                       bytes([65 + rng.randrange(26)])
                                       * (18 * L_BSIZE)), None))
    return steps


L_BSIZE = 4096


# Handcrafted sequences hitting specific edges: duplicate creates in one
# batch, unlink-then-create reusing the slot, chain cancellation mid-batch,
# lookups racing creates, writes to an unlinked ino (ESTALE path).
HANDMADE: List[List[Tuple]] = [
    [("create", (1, "a"), False), ("create", (1, "a"), False),
     ("lookup", (1, "a"), False), ("unlink", (1, "a"), False),
     ("unlink", (1, "a"), False), ("create", (1, "a"), False)],
    [("create", (0, "x"), True), ("create", (0, "x"), True),
     ("create", (0, "y"), False),  # 2nd link fails EEXIST -> y ECANCELED
     ("lookup", (0, "y"), False)],
    [("chain_cw", (2, "a", b"payload-one"), None),
     ("chain_cw", (2, "a", b"payload-two"), None),  # EEXIST cancels write
     ("read", (0, 0, 50), False)],
    [("mkdir", (1, "sub"), False), ("create", (1, "sub"), False),
     ("unlink", (1, "sub"), False),  # EISDIR
     ("lookup", (1, "sub"), False)],
    [("unlink", (0, "nope"), True), ("create", (0, "after"), False),
     ("lookup", (0, "after"), False)],  # failed link cancels the create
    [("write", (0, 0, b"W" * 123), True), ("read", (0, 0, 123), True),
     ("fsync", (0,), False),
     ("getattr_dir", (0,), False)],
    # chain whose write exceeds ONE MAXOP_BLOCKS (16) reservation: the
    # batched side runs it as a single chain transaction (chain-aware
    # reservation), the scalar side as per-sub-op reservations — results
    # and trees must still match
    [("write", (0, 0, b"J" * (20 * 4096)), True),
     ("read", (0, 0, 20 * 4096), True), ("fsync", (0,), False),
     ("read", (1, 0, 64), False)],
    # deep chain: linked multi-block writes whose cumulative footprint
    # exceeds one reservation (but fits the journal)
    [("write", (0, 0, b"a" * (4 * 4096)), True),
     ("write", (0, 4 * 4096, b"b" * (4 * 4096)), True),
     ("write", (0, 8 * 4096, b"c" * (4 * 4096)), True),
     ("write", (0, 12 * 4096, b"d" * (4 * 4096)), True),
     ("fsync", (0,), False),
     ("getattr_dir", (0,), False)],
    # chained create→write with a multi-block payload (PrevResult feeding
    # a >MAXOP chain), then a drain barrier entry after the chain
    [("chain_cw", (2, "big", b"k" * (18 * 4096)), None),
     ("read", (0, 0, 50), "drain"),
     ("lookup", (2, "big"), False)],
]


def _entries_for(steps, dirs, files) -> List[SubmissionEntry]:
    """Concrete SubmissionEntry list for one twin's namespace."""
    out: List[SubmissionEntry] = []
    uid = 0
    for op, spec, link in steps:
        # link spec: True -> SQE_LINK, "drain" -> SQE_DRAIN barrier
        flags = SQE_LINK if link is True else \
            (SQE_DRAIN if link == "drain" else 0)
        if op == "chain_cw":
            d, name, data = spec
            out.append(SubmissionEntry("create", (dirs[d], name),
                                       user_data=uid, flags=SQE_LINK))
            out.append(SubmissionEntry("write", (PrevResult("ino"), 0, data),
                                       user_data=uid + 1))
            uid += 2
            continue
        if op in ("create", "unlink", "mkdir", "lookup"):
            d, name = spec
            args = (dirs[d], name)
        elif op in ("write", "read"):
            f = spec[0]
            args = (files[f],) + tuple(spec[1:])
        elif op in ("getattr_dir", "readdir"):
            args = (dirs[spec[0]],)
            op = "getattr" if op == "getattr_dir" else "readdir"
        elif op == "fsync":
            args = (files[spec[0]],)
        out.append(SubmissionEntry(op, args, user_data=uid, flags=flags))
        uid += 1
    return out


def _norm(res):
    """Comparable form of a completion result."""
    if isinstance(res, Attr):
        return ("attr", res.ino, int(res.kind), res.size, res.nlink)
    if isinstance(res, list):  # readdir
        return sorted((n, i, int(k)) for n, i, k in res)
    if isinstance(res, dict):  # statfs — commit counts may differ; drop
        return "statfs"
    return res


def _run_scalar_reference(mount, entries) -> List[Tuple]:
    """Execute entries one scalar dispatch at a time, emulating the
    documented chain rule by hand. Returns (user_data, errno, result)."""
    out: List[Tuple] = []
    chain_results: List = []   # results of the current chain so far
    in_chain = False
    cancelled = False
    for e in entries:
        starts_chain = bool(e.flags & SQE_LINK) and not in_chain
        if starts_chain:
            in_chain, cancelled, chain_results = True, False, []
        if in_chain and cancelled:
            out.append((e.user_data, Errno.ECANCELED, None))
        else:
            args = tuple(
                (getattr(chain_results[-a.back], a.attr)
                 if a.attr else chain_results[-a.back])
                if isinstance(a, PrevResult) else a
                for a in e.args)
            try:
                res = mount.call(e.op, *args)
                out.append((e.user_data, None, _norm(res)))
                chain_results.append(res)
            except FsError as err:
                out.append((e.user_data, err.errno, None))
                if in_chain:
                    cancelled = True
                chain_results.append(None)
        if in_chain and not (e.flags & SQE_LINK):
            in_chain = False  # chain tail reached
    return out


def _tree(view, mount, path="") -> Dict:
    """Recursive logical snapshot: names, kinds, nlinks, file contents."""
    snap: Dict = {}
    ino = view._walk(path or "/")
    for name, child_ino, kind in sorted(mount.call("readdir", ino)):
        attr = mount.call("getattr", child_ino)
        key = f"{path}/{name}"
        if attr.is_dir:
            snap[key] = ("dir", attr.nlink, _tree(view, mount, key))
        else:
            data = mount.call("read", child_ino, 0, attr.size)
            snap[key] = ("file", attr.nlink, data)
    return snap


def _assert_equivalent(kind: str, steps: List[Tuple],
                       batch_sizes: Optional[List[int]] = None):
    mf_s, dirs_s, files_s = _setup(kind)
    mf_b, dirs_b, files_b = _setup(kind)
    try:
        assert dirs_s == dirs_b and files_s == files_b, \
            "twin setup must yield identical inos"
        entries_s = _entries_for(steps, dirs_s, files_s)
        entries_b = _entries_for(steps, dirs_b, files_b)
        scalar = _run_scalar_reference(mf_s.mount, entries_s)

        # batched side: split into submissions, never severing a chain
        batched: List[Tuple] = []
        i, n = 0, len(entries_b)
        sizes = batch_sizes or [n]
        si = 0
        while i < n:
            j = min(i + max(1, sizes[si % len(sizes)]), n)
            while j < n and entries_b[j - 1].flags & SQE_LINK:
                j += 1  # keep the chain whole
            comps = mf_b.mount.submit(entries_b[i:j])
            assert [c.user_data for c in comps] == \
                [e.user_data for e in entries_b[i:j]], "completion order"
            batched.extend((c.user_data, c.errno, _norm(c.result))
                           for c in comps)
            i = j
            si += 1

        assert [(u, e) for u, e, _ in scalar] == \
            [(u, e) for u, e, _ in batched], \
            f"errno vectors diverge\nscalar:  {scalar}\nbatched: {batched}"
        assert [r for _, _, r in scalar] == [r for _, _, r in batched], \
            "per-entry results diverge"
        assert _tree(mf_s.view, mf_s.mount) == _tree(mf_b.view, mf_b.mount), \
            "final filesystem trees diverge"
    finally:
        mf_s.close()
        mf_b.close()


# --- deterministic corpus (always runs) -----------------------------------------


@pytest.mark.parametrize("kind", ["bento", "vfs", "ext4like"])
@pytest.mark.parametrize("case", range(len(HANDMADE)))
def test_handmade_sequences_equivalent(kind, case):
    _assert_equivalent(kind, HANDMADE[case], batch_sizes=[3, 2])


@pytest.mark.parametrize("kind", ["bento", "ext4like"])
@pytest.mark.parametrize("seed", [1, 2, 3, 4, 5])
def test_seeded_random_sequences_equivalent(kind, seed):
    steps = gen_steps(random.Random(seed), 40)
    _assert_equivalent(kind, steps, batch_sizes=[1, 7, 16, 4])


@pytest.mark.parametrize("kind", ["bento", "vfs", "ext4like"])
@pytest.mark.parametrize("seed", [11, 12, 13])
def test_deep_chain_sequences_equivalent(kind, seed):
    """Chains exceeding one MAXOP_BLOCKS reservation: the chain-aware
    journal reservation path (one transaction per chain) must be
    byte-identical to scalar execution (per-sub-op reservations)."""
    steps = gen_deep_chain_steps(random.Random(seed), 5)
    _assert_equivalent(kind, steps, batch_sizes=[3, 9])


def test_fuse_equivalence_smoke():
    """One seeded sequence through the FUSE daemon (chains cross the
    socket as one round trip); kept small — each op forks real I/O."""
    _assert_equivalent("fuse", gen_steps(random.Random(9), 12),
                       batch_sizes=[5])


# --- parallel multi-submitter drain: scalar-vs-parallel differential ------------
#
# The sharded-lock-domain executor must be INVISIBLE: draining the same
# multi-segment submission through the footprint-scheduled worker pool
# and through the serial path must produce identical per-segment
# completion vectors and identical final trees. Overlapping footprints
# (ALLOC on every mutation, shared inode stripes) are ordered by
# dependency edges in flat submission order, so outcomes are
# deterministic even with all segments mutating.


def gen_readonly_steps(rng: random.Random, n: int) -> List[Tuple]:
    """Read-only op sequence (lookup/read/getattr/readdir) — safe to run
    concurrently with a mutating segment on another lock domain."""
    steps: List[Tuple] = []
    for _ in range(n):
        r = rng.random()
        d = rng.randrange(3)
        if r < 0.3:
            steps.append(("lookup", (d, rng.choice(NAMES)),
                          rng.random() < 0.3))
        elif r < 0.6:
            steps.append(("read", (rng.randrange(2), rng.randrange(3) * 100,
                                   rng.randrange(1, 300)),
                          rng.random() < 0.3))
        elif r < 0.8:
            steps.append(("getattr_dir", (d,), False))
        else:
            steps.append(("readdir", (d,), False))
    return steps


def _run_multi(kind: str, seg_steps: List[List[Tuple]], pool):
    from repro.core.interface import execute_multi_batch

    mf, dirs, files = _setup(kind)
    try:
        fs = mf.mount.module
        segs = [_entries_for(steps, dirs, files) for steps in seg_steps]
        res = execute_multi_batch(fs.submit_batch, segs, pool=pool)
        out = [[(c.user_data, c.errno, _norm(c.result)) for c in seg]
               for seg in res]
        return out, _tree(mf.view, mf.mount)
    finally:
        mf.close()


@pytest.mark.parametrize("kind", ["bento", "ext4like"])
@pytest.mark.parametrize("seed", [21, 22, 23])
def test_parallel_drain_equivalent_one_mutator_many_readers(kind, seed):
    """One mutating segment + three read-only segments on the same
    namespace: parallel drain == serial drain, completions and tree."""
    import concurrent.futures as cf

    rng = random.Random(seed)
    seg_steps = [gen_steps(rng, 24)] + \
        [gen_readonly_steps(rng, 12) for _ in range(3)]
    ser = _run_multi(kind, seg_steps, None)
    with cf.ThreadPoolExecutor(max_workers=4) as pool:
        par = _run_multi(kind, seg_steps, pool)
    assert par[0] == ser[0], "per-segment completion vectors diverge"
    assert par[1] == ser[1], "final filesystem trees diverge"


@pytest.mark.parametrize("kind", ["bento", "ext4like", "dedup-bento"])
def test_parallel_drain_equivalent_all_segments_mutating(kind):
    """Every segment mutates (name collisions across segments included):
    ALLOC-domain edges serialize the groups in flat submission order, so
    the parallel executor must reproduce the serial outcome exactly —
    on dedup mounts the BLOCKSTORE domain degenerates the schedule to
    fully serial and must still match."""
    import concurrent.futures as cf

    seg_steps = [gen_steps(random.Random(100 + i), 20) for i in range(4)]
    ser = _run_multi(kind, seg_steps, None)
    with cf.ThreadPoolExecutor(max_workers=4) as pool:
        par = _run_multi(kind, seg_steps, pool)
    assert par[0] == ser[0], "per-segment completion vectors diverge"
    assert par[1] == ser[1], "final filesystem trees diverge"


@pytest.mark.parametrize("seed", [41, 42])
def test_parallel_drain_equivalent_deep_chains(seed):
    """Multi-block linked chains in the mutating segment: the chain
    transaction executes on a worker under its group's domain scope and
    must stay byte-identical to the serial drain."""
    import concurrent.futures as cf

    rng = random.Random(seed)
    seg_steps = [gen_deep_chain_steps(rng, 4)] + \
        [gen_readonly_steps(rng, 10) for _ in range(2)]
    ser = _run_multi("bento", seg_steps, None)
    with cf.ThreadPoolExecutor(max_workers=4) as pool:
        par = _run_multi("bento", seg_steps, pool)
    assert par[0] == ser[0]
    assert par[1] == ser[1]


# --- property-based exploration (optional hypothesis) ---------------------------


if hp is not None:
    @hp.given(seed=st.integers(0, 2**32 - 1),
              nsteps=st.integers(5, 60),
              batch_sizes=st.lists(st.integers(1, 20), min_size=1,
                                   max_size=5))
    @hp.settings(max_examples=25, deadline=None)
    def test_random_sequences_equivalent_property(seed, nsteps, batch_sizes):
        steps = gen_steps(random.Random(seed), nsteps)
        _assert_equivalent("bento", steps, batch_sizes=batch_sizes)

    @hp.given(seed=st.integers(0, 2**32 - 1))
    @hp.settings(max_examples=10, deadline=None)
    def test_random_sequences_equivalent_ext4like(seed):
        steps = gen_steps(random.Random(seed), 40)
        _assert_equivalent("ext4like", steps, batch_sizes=[8])

    @hp.given(seed=st.integers(0, 2**32 - 1),
              n_chains=st.integers(2, 7))
    @hp.settings(max_examples=10, deadline=None)
    def test_deep_chain_sequences_equivalent_property(seed, n_chains):
        steps = gen_deep_chain_steps(random.Random(seed), n_chains)
        _assert_equivalent("bento", steps, batch_sizes=[4, 11])
