"""Differential equivalence harness for CoW overlay mounts (the headline
test of the lazy-materialization PR): N overlay tenants provisioned over
ONE shared base image must be operation-for-operation equivalent — POSIX
view AND errnos — to N mounts that each got a FULL byte-for-byte copy of
the image. Copy-up, whiteouts, opaque directories and the lazy fetch path
must all be invisible to the application.

Every step executes by PATH through ``PosixView`` on both twins (inos
differ by design — the overlay tags base inos), the per-step
result-or-errno vectors must match exactly, and the final trees are
compared by name, kind, and file content.

Deliberately OUT of corpus (documented overlayfs-parity divergences, each
pinned by its own unit test below instead):

* directory renames and renames displacing a directory — the overlay
  answers EXDEV for base-backed/merged directories (real overlayfs does
  the same; callers must recurse);
* reserved overlay names (``.bento-opq``, ``.bento-cowtmp.*``) — EPERM;
* directory nlink/size attributes (an upper mirror dir does not count
  base children) — tree comparison checks names/kinds/content, not those.

Runs everywhere: a deterministic corpus (seeded random.Random sequences +
handcrafted edge cases) always executes; when hypothesis is available a
property-based version explores further.
"""

from __future__ import annotations

import random
from typing import Dict, List, Tuple

import pytest

from repro.core.interface import Attr, Errno, FsError
from repro.fs.mounts import (MountedFs, build_base_image, make_mount,
                             overlay_tenant)
from repro.fs.overlay import OPAQUE_MARK, OverlayFilesystem
from repro.fs.posix import PosixView

try:
    import hypothesis as hp
    import hypothesis.strategies as st
except ImportError:  # deterministic corpus still runs
    hp = None
    st = None


# --- twin construction ------------------------------------------------------------


def _copy_twin(image, fs_kind: str) -> MountedFs:
    """The reference: a mount over a FULL byte-for-byte copy of the base
    image (what benchmarks/fs_coldstart.py times as the naive baseline)."""
    from repro.core.registry import mount as bento_mount
    from repro.core.services import kernel_binding
    from repro.fs.blockdev import MemBlockDevice
    from repro.fs.ext4like import Ext4LikeFileSystem
    from repro.fs.xv6 import Xv6FileSystem, Xv6Options

    dev = MemBlockDevice(image.n_blocks)
    dev._data = image._data.copy()
    ks = kernel_binding(dev)
    cls = Ext4LikeFileSystem if fs_kind == "ext4like" else Xv6FileSystem
    fs = cls(Xv6Options(group_commit=True, batched_install=True))
    m = bento_mount("copy-twin", ks, module=fs)
    return MountedFs("full-copy", m, PosixView(m), ks, dev)


def _twins(image, fs_kind: str) -> Tuple[MountedFs, MountedFs]:
    return overlay_tenant(image, fs_kind), _copy_twin(image, fs_kind)


# --- op-sequence model ------------------------------------------------------------
#
# Steps are path-based. Separate file/dir name pools keep renames
# file-to-file (directory renames are the documented EXDEV divergence).
# Base names collide with corpus names on purpose: unlink-a-base-name
# (whiteout), recreate-over-whiteout, write-a-base-file (copy-up) and
# rmdir-a-base-dir (opaque recreate) all happen naturally.

DIRS = ["/", "/etc", "/usr", "/usr/share", "/sub", "/etc/sub"]
FILE_NAMES = ["hostname", "motd", "readme", "words", "fa", "fb"]
DIR_NAMES = ["share", "sub", "detc"]


def gen_steps(rng: random.Random, n: int) -> List[Tuple]:
    steps: List[Tuple] = []
    for _ in range(n):
        r = rng.random()
        d = rng.choice(DIRS)
        name = rng.choice(FILE_NAMES)
        path = (d.rstrip("/") + "/" + name)
        if r < 0.14:
            steps.append(("write_file", path,
                          bytes([65 + rng.randrange(26)])
                          * rng.randrange(1, 9000)))
        elif r < 0.24:
            steps.append(("unlink", path))
        elif r < 0.32:
            steps.append(("mkdir", d.rstrip("/") + "/"
                          + rng.choice(DIR_NAMES)))
        elif r < 0.40:
            steps.append(("rmdir", d.rstrip("/") + "/"
                          + rng.choice(DIR_NAMES)))
        elif r < 0.50:
            steps.append(("read_file", path))
        elif r < 0.58:
            steps.append(("append", path,
                          bytes([97 + rng.randrange(26)])
                          * rng.randrange(1, 500)))
        elif r < 0.66:
            steps.append(("truncate", path, rng.randrange(0, 2000)))
        elif r < 0.76:
            d2 = rng.choice(DIRS)
            steps.append(("rename", path,
                          d2.rstrip("/") + "/" + rng.choice(FILE_NAMES)))
        elif r < 0.84:
            steps.append(("listdir", d))
        elif r < 0.92:
            steps.append(("stat", path))
        else:
            steps.append(("exists", path))
    return steps


# Handcrafted sequences pinning specific overlay mechanics to the
# full-copy semantics: whiteouts masking base names, recreation over a
# whiteout, copy-up on write/append/truncate, opaque directories hiding a
# deleted base dir's children, cross-directory file renames off the base.
HANDMADE: List[List[Tuple]] = [
    # whiteout + recreate: delete a base name, list, recreate, read
    [("unlink", "/etc/motd"), ("listdir", "/etc"),
     ("exists", "/etc/motd"), ("read_file", "/etc/motd"),
     ("write_file", "/etc/motd", b"reborn"), ("read_file", "/etc/motd"),
     ("listdir", "/etc")],
    # copy-up: overwrite (shorter than base — tail semantics must match),
    # append, truncate, each against base-backed files
    [("write_file", "/etc/hostname", b"T"), ("read_file", "/etc/hostname"),
     ("append", "/etc/motd", b"+tail"), ("read_file", "/etc/motd"),
     ("truncate", "/usr/share/words", 10),
     ("read_file", "/usr/share/words"), ("stat", "/usr/share/words")],
    # opaque dir: empty a base dir, rmdir it, recreate — the new dir must
    # NOT show the dead base children; nested mkdir under a base dir
    [("rmdir", "/usr/share"), ("unlink", "/usr/share/words"),
     ("rmdir", "/usr/share"), ("listdir", "/usr"),
     ("mkdir", "/usr/share"), ("listdir", "/usr/share"),
     ("write_file", "/usr/share/fresh", b"new"), ("listdir", "/usr/share")],
    # cross-directory rename of a base file (copy-up + whiteout) and
    # rename ONTO a base name (displacement)
    [("rename", "/readme", "/etc/readme"), ("exists", "/readme"),
     ("read_file", "/etc/readme"), ("listdir", "/"), ("listdir", "/etc"),
     ("rename", "/etc/readme", "/etc/hostname"),
     ("read_file", "/etc/hostname"), ("listdir", "/etc")],
    # errno parity: ENOENT / EEXIST / EISDIR / ENOTDIR / ENOTEMPTY
    [("read_file", "/nope"), ("unlink", "/nope"), ("mkdir", "/etc"),
     ("unlink", "/usr"), ("rmdir", "/etc/hostname"),
     ("rmdir", "/usr"), ("rename", "/nope", "/etc/x"),
     ("mkdir", "/etc/hostname/sub"), ("listdir", "/etc/hostname")],
    # mirror-dir chain: deep creates under an untouched base dir
    [("mkdir", "/usr/share/sub"), ("write_file", "/usr/share/sub/f", b"x"),
     ("read_file", "/usr/share/sub/f"), ("listdir", "/usr/share"),
     ("listdir", "/usr/share/sub"), ("rename", "/usr/share/sub/f", "/top"),
     ("read_file", "/top"), ("listdir", "/usr/share/sub")],
    # unlink EVERY base name, then rebuild some of it
    [("unlink", "/etc/hostname"), ("unlink", "/etc/motd"),
     ("unlink", "/usr/share/words"), ("unlink", "/readme"),
     ("listdir", "/etc"), ("listdir", "/usr/share"), ("listdir", "/"),
     ("write_file", "/etc/hostname", b"v2"), ("listdir", "/etc"),
     ("read_file", "/etc/hostname")],
]


def _norm(res):
    if isinstance(res, Attr):
        # inos differ by design (BASE_BIT tags); dir nlink/size are the
        # documented attr divergence — compare kind, and size for files
        return ("dir",) if res.is_dir else ("file", res.size)
    if isinstance(res, list):
        return sorted(res)
    return res


def _apply(view: PosixView, step: Tuple):
    op, args = step[0], step[1:]
    try:
        res = getattr(view, op)(*args)
        if isinstance(res, (Attr, list)):
            res = _norm(res)
        return ("ok", res)
    except FsError as e:
        return ("err", int(e.errno))


def _tree(view: PosixView, path: str = "") -> Dict:
    """Logical snapshot by NAME: kinds + file contents (no inos, no dir
    attrs — the documented divergences)."""
    snap: Dict = {}
    m = view.m
    ino = view._walk(path or "/")
    for name, child_ino, _k in sorted(m.call("readdir", ino)):
        attr = m.call("getattr", child_ino)
        key = f"{path}/{name}"
        if attr.is_dir:
            snap[key] = ("dir", _tree(view, key))
        else:
            snap[key] = ("file", m.call("read", child_ino, 0, attr.size))
    return snap


def _assert_equivalent(fs_kind: str, steps: List[Tuple], *, image=None,
                       n_tenants: int = 1):
    """N overlay tenants over ONE image vs N full-copy twins, every step
    compared; then the final trees, then base-image immutability."""
    image = image if image is not None else build_base_image(fs_kind)
    img0 = image._data.tobytes()
    pairs = [_twins(image, fs_kind) for _ in range(n_tenants)]
    try:
        for t, (ov, cp) in enumerate(pairs):
            for i, step in enumerate(steps):
                got, want = _apply(ov.view, step), _apply(cp.view, step)
                assert got == want, (
                    f"tenant {t} step {i} {step!r} diverged:\n"
                    f"  overlay:   {got!r}\n  full-copy: {want!r}")
            assert _tree(ov.view) == _tree(cp.view), \
                f"tenant {t}: final trees diverge"
        assert image._data.tobytes() == img0, \
            "an overlay tenant dirtied the shared base image"
    finally:
        for ov, cp in pairs:
            ov.close()
            cp.close()


# --- deterministic corpus (always runs) -------------------------------------------


@pytest.mark.parametrize("fs_kind", ["xv6", "ext4like"])
@pytest.mark.parametrize("case", range(len(HANDMADE)))
def test_handmade_sequences_equivalent(fs_kind, case):
    _assert_equivalent(fs_kind, HANDMADE[case])


@pytest.mark.parametrize("fs_kind", ["xv6", "ext4like"])
@pytest.mark.parametrize("seed", [1, 2, 3, 4, 5])
def test_seeded_random_sequences_equivalent(fs_kind, seed):
    _assert_equivalent(fs_kind, gen_steps(random.Random(seed), 60))


def test_many_tenants_one_image_equivalent_and_isolated():
    """The provisioning story end-to-end: four tenants share ONE image,
    each runs a DIFFERENT seeded sequence, each must match its own
    full-copy twin (which also proves tenants can't see each other), and
    the image survives byte-identical."""
    image = build_base_image("xv6")
    img0 = image._data.tobytes()
    for seed in (11, 12, 13, 14):
        _assert_equivalent("xv6", gen_steps(random.Random(seed), 40),
                           image=image)
    assert image._data.tobytes() == img0


# --- the documented divergences, pinned ---------------------------------------------


def test_base_dir_rename_answers_exdev():
    """Renaming a base-backed or merged directory crosses the base/upper
    line: the overlay answers EXDEV (real-overlayfs parity), where a
    full-copy mount would just rename. Upper-only directories rename
    normally."""
    image = build_base_image("xv6")
    mf = overlay_tenant(image, "xv6")
    try:
        with pytest.raises(FsError) as ei:
            mf.view.rename("/usr/share", "/shr")
        assert ei.value.errno == Errno.EXDEV
        # displacement: renaming a file ONTO a merged dir is EXDEV too
        with pytest.raises(FsError) as ei:
            mf.view.rename("/readme", "/usr/share")
        assert ei.value.errno == Errno.EXDEV
        # a pure-upper dir renames fine
        mf.view.mkdir("/fresh")
        mf.view.write_file("/fresh/f", b"x")
        mf.view.rename("/fresh", "/moved")
        assert mf.view.read_file("/moved/f") == b"x"
    finally:
        mf.close()


def test_reserved_overlay_names_rejected():
    image = build_base_image("xv6")
    mf = overlay_tenant(image, "xv6")
    try:
        for bad in (OPAQUE_MARK, ".bento-cowtmp.7"):
            with pytest.raises(FsError) as ei:
                mf.view.write_file("/" + bad, b"x")
            assert ei.value.errno == Errno.EPERM
        with pytest.raises(FsError):
            mf.view.mkdir("/" + OPAQUE_MARK)
    finally:
        mf.close()


def test_base_immutability_enforced_at_the_device():
    """immutable_base on the tenant's lazy device is a hard floor under
    the overlay logic: even a direct write into the base range raises."""
    from repro.fs.blockdev import BlockDeviceError

    image = build_base_image("xv6")
    mf = overlay_tenant(image, "xv6")
    try:
        lazy = mf.mount.module.opts.base_dev
        with pytest.raises(BlockDeviceError):
            lazy.write_block(1, b"\0" * lazy.block_size)
    finally:
        mf.close()


def test_overlay_kinds_in_mount_matrix():
    """make_mount speaks overlay-bento / overlay-ext4like directly (each
    builds its own default-populated image — the matrix entry)."""
    for kind in ("overlay-bento", "overlay-ext4like"):
        mf = make_mount(kind)
        try:
            assert isinstance(mf.mount.module, OverlayFilesystem)
            assert mf.view.read_file("/etc/hostname") == b"golden\n"
            mf.view.write_file("/etc/hostname", b"mine!!!")
            assert mf.view.read_file("/etc/hostname") == b"mine!!!"
        finally:
            mf.close()


def test_cold_remount_preserves_tenant_state():
    """Unmount-then-remount of the UPPER (same devices, fresh fs
    instances, fresh lazy cache): whiteouts, copy-ups and opaque dirs all
    survive — the overlay's session maps are rebuildable state, not
    load-bearing memory."""
    from repro.core.registry import mount as bento_mount
    from repro.core.services import kernel_binding
    from repro.fs.blockdev import LazyBlockDevice
    from repro.fs.overlay import OverlayOptions

    image = build_base_image("xv6")
    mf = overlay_tenant(image, "xv6")
    upper_dev = mf.dev
    mf.view.unlink("/etc/motd")
    mf.view.write_file("/etc/hostname", b"tenant-own\n")
    mf.mount.unmount()

    lazy = LazyBlockDevice(image, n_blocks=image.n_blocks,
                           immutable_base=True)
    fs = OverlayFilesystem(OverlayOptions(kind="xv6", base_dev=lazy))
    m2 = bento_mount("overlay-remount", kernel_binding(upper_dev), module=fs)
    v2 = PosixView(m2)
    try:
        assert not v2.exists("/etc/motd")
        assert v2.read_file("/etc/hostname") == b"tenant-own\n"
        assert v2.read_file("/usr/share/words") == b"alpha beta gamma delta\n" * 64
    finally:
        m2.unmount()


# --- property-based exploration (optional hypothesis) -----------------------------


if hp is not None:
    @hp.given(seed=st.integers(0, 2**32 - 1), nsteps=st.integers(5, 80))
    @hp.settings(max_examples=20, deadline=None)
    def test_random_sequences_equivalent_property(seed, nsteps):
        _assert_equivalent("xv6", gen_steps(random.Random(seed), nsteps))

    @hp.given(seed=st.integers(0, 2**32 - 1))
    @hp.settings(max_examples=8, deadline=None)
    def test_random_sequences_equivalent_ext4like(seed):
        _assert_equivalent("ext4like", gen_steps(random.Random(seed), 50))
