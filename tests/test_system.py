"""End-to-end behaviour of the whole system: train a small model for real
steps (loss must drop), write checkpoints through the Bento FS, survive an
injected node failure mid-run, hot-upgrade the mounted fs under the
trainer, then serve from the trained weights — the paper's high-velocity
story exercised end to end.
"""

import jax
import jax.numpy as jnp

from repro.configs import registry
from repro.core.upgrade import upgrade
from repro.distributed.sharding import ShardingCtx
from repro.fs.ext4like import Ext4LikeFileSystem
from repro.fs.mounts import make_mount
from repro.serve.step import make_decode_step, make_prefill_step
from repro.train.trainer import Trainer, WorkerFailure


def test_end_to_end_train_fail_upgrade_serve():
    b = registry.get("smollm-135m")
    cfg = b.smoke
    run = b.run.replace(microbatch_per_data_shard=0, learning_rate=1e-3)
    mf = make_mount("bento", n_blocks=32768)

    armed = {"on": True}

    def failure_hook(step):
        if step == 6 and armed["on"]:
            armed["on"] = False
            raise WorkerFailure("rack power glitch")

    t = Trainer(cfg, run, global_batch=8, seq_len=64, ckpt_view=mf.view,
                ckpt_every=3, failure_hook=failure_hook, seed=3)
    t.train(12)

    losses = [m["loss"] for m in t.metrics_log]
    assert t.recoveries == 1
    assert t.step_idx == 12
    # training must actually learn (synthetic data: loss drops from ~ln V)
    assert losses[-1] < losses[0], (losses[0], losses[-1])

    # hot-upgrade the checkpoint store's fs mid-run: xv6 -> ext4like
    def migrate(state, _o, _n):
        state.setdefault("dirindex", {})
        return state

    stats = upgrade(mf.mount, Ext4LikeFileSystem(), migrate=migrate)
    assert stats["total_s"] < 5.0

    # checkpoints are still readable through the upgraded fs
    t2 = Trainer(cfg, run, global_batch=8, seq_len=64, ckpt_view=mf.view,
                 seed=3)
    assert t2.restore_checkpoint()
    assert t2.step_idx == 12

    # serve from the trained weights
    ctx = ShardingCtx.null()
    prefill = jax.jit(make_prefill_step(cfg, run, ctx))
    decode = jax.jit(make_decode_step(cfg, run, ctx))
    toks = jnp.ones((2, 16), jnp.int32)
    tok, cache = prefill(t2.params, {"tokens": toks})
    cache = jax.tree.map(
        lambda x: jnp.pad(x, [(0, 0), (0, 0), (0, 8), (0, 0), (0, 0)])
        if x.ndim == 5 else x, cache)
    for i in range(4):
        tok, cache = decode(t2.params, cache,
                            {"tokens": tok[:, None], "pos": jnp.int32(16 + i)})
        assert tok.shape == (2,)
    mf.close()


def test_elastic_rescale_roundtrip():
    """Extract -> rebuild (null ctx <-> 1-device mesh) -> restore: the same
    §4.8 machinery that re-shards onto a grown pod."""
    from repro.launch.mesh import make_host_mesh

    b = registry.get("smollm-135m")
    run = b.run.replace(microbatch_per_data_shard=0)
    t = Trainer(b.smoke, run, global_batch=4, seq_len=32)
    t.train(3)
    t.elastic_rescale(make_host_mesh(1, 1))
    assert t.step_idx == 3
    t.train(5)
    assert t.metrics_log[-1]["loss"] > 0
    # determinism across the rescale: compare to an uninterrupted run
    t2 = Trainer(b.smoke, run, global_batch=4, seq_len=32)
    t2.train(5)
    assert abs(t2.metrics_log[-1]["loss"] - t.metrics_log[-1]["loss"]) < 1e-3
