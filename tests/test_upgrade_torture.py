"""Upgrade-under-load torture: hot-swapping the provenance layer onto a
LIVE mount (plain → prov → plain) must be invisible to concurrent
submitters — the paper's §6 demo, made falsifiable.

Three proof shapes:

* **Deterministic phases** — single submitter, barriered: ops issued
  before the wrap must NOT be in the provenance log, ops issued between
  wrap and unwrap must ALL be there in execution order, ops after the
  unwrap again must not. Exact log-content equality, on the in-process
  mounts AND through the FUSE daemon (the swap crosses the address-space
  boundary via the ctl channel).

* **Under load** — M submitter threads hammer ``mount.submit`` with
  chained create→write rounds while the main thread swaps mid-stream.
  Per submitter: (a) zero lost/duplicated/reordered completions — every
  batch's completions match its submissions exactly; (b) rounds whose
  generation observations pin them inside the prov window appear in the
  log, rounds pinned outside do not, and each submitter's logged rounds
  form one contiguous window (a swap can tear a submitter's stream at
  most at the two swap points); (c) the measured freeze pause is bounded
  and reported.

* **Exhaustive matrix** (``--runslow``) — more submitters, more swap
  cycles, all mount kinds.
"""

import threading
import time

import pytest

from repro.core.interface import (Errno, FsError, PrevResult, SQE_LINK,
                                  SubmissionEntry)
from repro.core.upgrade import unwrap_layer, wrap_layer
from repro.fs.mounts import make_mount
from repro.fs.prov import PROV_LOG_NAME, ProvFilesystem


# --- shared plumbing --------------------------------------------------------------


def _swap_on(mf):
    """Wrap the prov layer onto a live mount, whatever the mount kind;
    returns (pause_s, prov_generation)."""
    if mf.kind == "fuse":
        res = mf.mount.wrap_prov()
        return res["pause_s"], res["generation"]
    stats = wrap_layer(mf.mount, ProvFilesystem)
    return stats["total_s"], mf.mount.generation


def _swap_off(mf):
    if mf.kind == "fuse":
        return mf.mount.unwrap_prov()["pause_s"]
    return unwrap_layer(mf.mount)["total_s"]


def _generation(mf):
    if mf.kind == "fuse":
        return mf.mount.ctl("generation")
    return mf.mount.generation


def _read_log_rewrapped(mf):
    """Authoritative post-run log read: re-wrap (adopts the durable
    on-device log) and read every record, then strip the layer again."""
    _swap_on(mf)
    recs = mf.view.read_provenance()
    _swap_off(mf)
    return recs


# --- deterministic phases: exact log content --------------------------------------


@pytest.mark.parametrize("kind", ["bento", "ext4like", "fuse"])
def test_swap_captures_exactly_the_prov_window(kind):
    """Phase A (plain) → wrap → phase B (prov) → unwrap → phase C (plain):
    the log holds exactly phase B's mutations, in execution order, on the
    in-process mounts and through the FUSE daemon alike."""
    mf = make_mount(kind, n_blocks=4096)
    v = mf.view
    v.mkdir("/d")

    # phase A: plain — must never appear in the log
    v.create("/d/a0")
    v.write_file("/d/a0", b"A" * 2048, create=False)
    with pytest.raises(FsError):
        v.read_provenance()  # no layer mounted yet

    pause_on, _ = _swap_on(mf)
    # phase B: prov — every mutation logged, in order
    v.create("/d/b0")
    v.write_file("/d/b0", b"B" * 2048, create=False)
    v.mkdir("/d/sub")
    v.rename("/d/b0", "/d/sub/b1")
    v.unlink("/d/a0")
    live = v.read_provenance()
    pause_off = _swap_off(mf)

    # phase C: plain again — invisible to the log
    v.create("/d/c0")
    with pytest.raises(FsError):
        v.read_provenance()

    recs = _read_log_rewrapped(mf)
    assert [r["op"] for r in recs] == \
        ["create", "write", "mkdir", "rename", "unlink"]
    assert [r.get("name") for r in recs] == ["b0", "", "sub", "b0", "a0"]
    assert recs[3]["newname"] == "b1"
    assert [(r["op"], r.get("name")) for r in live] == \
        [(r["op"], r.get("name")) for r in recs], \
        "post-run log differs from the live view"
    # phase A/C names never leaked in
    assert not any(r.get("name") in ("a0", "c0") and r["op"] == "create"
                   for r in recs)
    # the log file itself stays hidden from the namespace while wrapped
    assert PROV_LOG_NAME not in v.listdir("/d")
    print(f"\n[{kind}] swap pause: on {pause_on*1e3:.2f} ms, "
          f"off {pause_off*1e3:.2f} ms")
    assert pause_on < 5.0 and pause_off < 5.0
    mf.close()


@pytest.mark.parametrize("kind", ["bento", "fuse"])
def test_log_survives_plain_window_and_rewrap(kind):
    """Downgrading strips the layer but the on-device log is durable:
    a later wrap adopts it and appends monotonically after it."""
    mf = make_mount(kind, n_blocks=4096)
    v = mf.view
    _swap_on(mf)
    v.create("/one")
    _swap_off(mf)
    v.create("/plainfile")           # plain window: not logged
    _swap_on(mf)
    v.create("/two")
    recs = v.read_provenance()
    assert [r.get("name") for r in recs if r["op"] == "create"] == \
        ["one", "two"]
    assert recs[-1]["seq"] > recs[0]["seq"]
    _swap_off(mf)
    mf.close()


def test_double_wrap_refused_cleanly():
    """Layers stack one deep: wrapping an already-wrapped mount must be
    refused BEFORE the gate freezes (never a half-installed module), and
    the mounted layer must keep serving."""
    from repro.core.upgrade import UpgradeError

    mf = make_mount("bento", n_blocks=2048)
    wrap_layer(mf.mount, ProvFilesystem)
    gen = mf.mount.generation
    with pytest.raises(UpgradeError):
        wrap_layer(mf.mount, ProvFilesystem)
    assert mf.mount.generation == gen
    mf.view.create("/still")
    assert mf.view.read_provenance()[-1]["name"] == "still"
    mf.close()


def test_reserved_log_name_is_guarded():
    """Applications cannot collide with the hidden log: creating,
    renaming onto, or unlinking the reserved root name is refused with a
    plain errno on both the scalar and the batched path."""
    from repro.core.interface import ROOT_INO

    mf = make_mount("bento", n_blocks=2048, prov=True)
    v = mf.view
    with pytest.raises(FsError) as ei:
        v.create(f"/{PROV_LOG_NAME}")
    assert ei.value.errno == Errno.EINVAL
    v.create("/x")
    with pytest.raises(FsError):
        v.rename("/x", f"/{PROV_LOG_NAME}")
    with pytest.raises(FsError) as ei:
        v.unlink(f"/{PROV_LOG_NAME}")
    assert ei.value.errno == Errno.ENOENT
    comps = mf.mount.submit([
        SubmissionEntry("create", (ROOT_INO, PROV_LOG_NAME), user_data=0),
        SubmissionEntry("create", (ROOT_INO, "ok"), user_data=1),
        SubmissionEntry("lookup", (ROOT_INO, PROV_LOG_NAME), user_data=2),
    ])
    assert comps[0].errno == Errno.EINVAL
    assert comps[1].ok
    assert comps[2].errno == Errno.ENOENT
    assert PROV_LOG_NAME not in v.listdir("/")
    # the hiding filter holds on the batched readdir path too (and the
    # batched query works through the layer, like the scalar one)
    comps = mf.mount.submit([
        SubmissionEntry("readdir", (ROOT_INO,), user_data=0),
        SubmissionEntry("read_provenance", (), user_data=1),
    ])
    assert PROV_LOG_NAME not in [t[0] for t in comps[0].result]
    assert comps[1].ok and comps[1].result[-1]["name"] == "ok"
    mf.close()


# --- under load: M submitters, swap mid-stream ------------------------------------


class _Submitter:
    """One thread's scripted stream of chained create→write rounds via
    ``mount.submit``, with completion-integrity checks and generation
    observations bracketing every round."""

    def __init__(self, mf, dino, t, payload=b"z" * 512, max_rounds=800):
        self.mf = mf
        self.dino = dino
        self.t = t
        self.payload = payload
        self.max_rounds = max_rounds  # caps device usage, not wall time
        self.rounds = []
        self.errors = []

    def run(self, stop):
        r = 0
        while not stop.is_set() and r < self.max_rounds:
            name = f"t{self.t}_r{r:05d}"
            entries = [
                SubmissionEntry("create", (self.dino, name),
                                user_data=(r, "c"), flags=SQE_LINK),
                SubmissionEntry("write", (PrevResult("ino"), 0, self.payload),
                                user_data=(r, "w")),
            ]
            g0 = _generation(self.mf)
            try:
                comps = self.mf.mount.submit(entries)
            except Exception as e:  # noqa: BLE001
                self.errors.append(f"t{self.t} r{r}: {type(e).__name__}: {e}")
                return
            g1 = _generation(self.mf)
            if [c.user_data for c in comps] != [(r, "c"), (r, "w")]:
                self.errors.append(
                    f"t{self.t} r{r}: lost/dup/reordered completions: "
                    f"{[c.user_data for c in comps]}")
            elif not (comps[0].ok and comps[1].ok
                      and comps[1].result == len(self.payload)):
                self.errors.append(
                    f"t{self.t} r{r}: bad completion "
                    f"{[(c.user_data, c.errno) for c in comps]}")
            self.rounds.append((name, g0, g1))
            r += 1


def _torture(kind, n_submitters, swap_cycles=1, phase_s=0.25,
             pause_budget_s=10.0, n_blocks=16384, max_rounds=800,
             mf=None):
    # callers may hand in a pre-built mount (overlay tenants over a
    # shared base image); the default builds a plain matrix entry
    mf = mf or make_mount(kind, n_blocks=n_blocks)
    v = mf.view
    subs = []
    for t in range(n_submitters):
        v.makedirs(f"/w{t}")
        subs.append(_Submitter(mf, v.stat(f"/w{t}").ino, t,
                               max_rounds=max_rounds))
    stop = threading.Event()
    threads = [threading.Thread(target=s.run, args=(stop,), daemon=True)
               for s in subs]
    for th in threads:
        th.start()
    pauses = []
    prov_gens = []
    time.sleep(phase_s)
    for _ in range(swap_cycles):
        p_on, gen = _swap_on(mf)
        prov_gens.append(gen)
        time.sleep(phase_s)
        pauses.append(p_on)
        pauses.append(_swap_off(mf))
        time.sleep(phase_s)
    stop.set()
    for th in threads:
        th.join(timeout=60)
    assert not any(th.is_alive() for th in threads), "submitter deadlocked"
    errors = [e for s in subs for e in s.errors]
    assert not errors, errors[:5]  # (a) zero lost/dup/reordered completions

    logged = {r["name"] for r in _read_log_rewrapped(mf)
              if r["op"] == "create"}
    prov_set = set(prov_gens)
    n_prov_certain = n_plain_certain = 0
    for s in subs:
        in_log = [name in logged for name, _, _ in s.rounds]
        # (b) logged rounds form ≤ swap_cycles contiguous windows
        edges = sum(1 for a, b in zip(in_log, in_log[1:]) if a != b)
        assert edges <= 2 * swap_cycles, \
            f"t{s.t}: {edges} log-window edges for {swap_cycles} cycles"
        for (name, g0, g1), lg in zip(s.rounds, in_log):
            if g0 == g1 and g0 in prov_set:
                n_prov_certain += 1
                assert lg, f"{name} completed under prov but is not logged"
            elif g0 == g1 and g0 not in prov_set:
                n_plain_certain += 1
                assert not lg, f"{name} completed plain yet logged"
    assert n_prov_certain > 0, "no round certainly ran under the prov layer"
    assert n_plain_certain > 0, "no round certainly ran plain"
    # every logged name belongs to the workload (the log invents nothing)
    assert all(n.startswith("t") and "_r" in n for n in logged)

    # (c) bounded, reported pause
    print(f"\n[{kind}] {n_submitters} submitters, "
          f"{sum(len(s.rounds) for s in subs)} rounds, "
          f"{n_prov_certain}/{n_plain_certain} certain prov/plain, "
          f"pauses {[f'{p*1e3:.1f}ms' for p in pauses]}")
    assert all(p < pause_budget_s for p in pauses), pauses
    # all files intact after the last downgrade (content spot checks)
    for s in subs:
        names = v.listdir(f"/w{s.t}")
        assert len(names) == len(s.rounds), \
            f"t{s.t}: {len(names)} files for {len(s.rounds)} rounds"
        assert v.read_file(f"/w{s.t}/{s.rounds[-1][0]}") == s.payload
    mf.close()


@pytest.mark.parametrize("kind", ["bento", "ext4like"])
def test_upgrade_torture_under_load(kind):
    _torture(kind, n_submitters=4)


def test_upgrade_torture_under_load_fuse():
    # generation observations ride the ctl channel; the swap lands between
    # two daemon service rounds, the address-space analogue of the gate
    _torture("fuse", n_submitters=3, phase_s=0.35)


@pytest.mark.parametrize("kind", ["overlay-bento", "overlay-ext4like"])
def test_upgrade_torture_on_overlay_tenant(kind):
    """Hot-swap prov onto a TENANT's writable upper mid-stream: the full
    under-load protocol (zero lost/dup completions, contiguous log
    windows, bounded pause) must hold on an overlay mount, and the shared
    base image must come out bit-identical — the layer stack only ever
    touches the upper."""
    from repro.fs.mounts import build_base_image, overlay_tenant

    fs_kind = {"overlay-bento": "xv6",
               "overlay-ext4like": "ext4like"}[kind]
    image = build_base_image(fs_kind)
    image_bytes0 = image._data.tobytes()
    image_writes0 = image.writes
    mf = overlay_tenant(image, fs_kind, kind=kind, n_blocks=16384,
                        ninodes=4096)  # 4 submitters x 800 rounds of files
    # merged reads from the base keep working across the whole swap dance
    assert mf.view.read_file("/etc/hostname") == b"golden\n"
    _torture(kind, n_submitters=4, mf=mf)
    assert image.writes == image_writes0, \
        "the prov swap dance wrote to the immutable base image"
    assert image._data.tobytes() == image_bytes0


def test_upgrade_mid_storm_pause_is_reported_and_bounded():
    """The §4.8 pause number under real contention: swap while the
    multi-submitter drain is saturated and assert the freeze stayed
    inside the budget (generous — CI machines jitter; the demo and the
    benchmark report the representative ~15 ms figure)."""
    mf = make_mount("bento", n_blocks=16384)
    v = mf.view
    v.makedirs("/w")
    dino = v.stat("/w").ino
    stop = threading.Event()
    errors = []

    def worker(t):
        i = 0
        while not stop.is_set():
            comps = mf.mount.submit([
                SubmissionEntry("create", (dino, f"s{t}_{i:05d}"),
                                user_data=0, flags=SQE_LINK),
                SubmissionEntry("write", (PrevResult("ino"), 0, b"x" * 256),
                                user_data=1)])
            if not all(c.ok for c in comps):
                errors.append([(c.user_data, c.errno) for c in comps])
                return
            i += 1

    threads = [threading.Thread(target=worker, args=(t,), daemon=True)
               for t in range(4)]
    for th in threads:
        th.start()
    time.sleep(0.2)
    on = wrap_layer(mf.mount, ProvFilesystem)
    time.sleep(0.2)
    off = unwrap_layer(mf.mount)
    stop.set()
    for th in threads:
        th.join(timeout=30)
    assert not errors, errors[:3]
    print(f"\npause under storm: on {on['total_s']*1e3:.2f} ms "
          f"(quiesce {on['quiesce_s']*1e3:.2f} ms), "
          f"off {off['total_s']*1e3:.2f} ms")
    assert on["total_s"] < 10 and off["total_s"] < 10
    mf.close()


def test_mixed_scalar_batched_reader_traffic_never_deadlocks():
    """Scalar namespace ops (fs lock → append lock), batched mutations and
    live ``read_provenance`` readers hammer one wrapped mount together:
    the layer's two locks must follow one global order or this wedges —
    the regression guard for the oplock→plock ordering."""
    mf = make_mount("bento", n_blocks=8192, prov=True)
    v, m = mf.view, mf.mount
    v.makedirs("/s")
    v.makedirs("/b")
    dino = v.stat("/b").ino
    stop = threading.Event()
    errs = []

    def scalar_worker(w):
        i = 0
        while not stop.is_set():
            try:
                v.create(f"/s/f{w}_{i}")
                v.unlink(f"/s/f{w}_{i}")
                i += 1
            except Exception as e:  # noqa: BLE001
                errs.append(repr(e))
                return

    def batch_worker(w):
        i = 0
        while not stop.is_set():
            try:
                comps = m.submit([
                    SubmissionEntry("create", (dino, f"g{w}_{i}")),
                    SubmissionEntry("unlink", (dino, f"g{w}_{i}"))])
                assert all(c.ok for c in comps), \
                    [(c.user_data, c.errno) for c in comps]
                i += 1
            except Exception as e:  # noqa: BLE001
                errs.append(repr(e))
                return

    def reader_worker(_w):
        while not stop.is_set():
            v.read_provenance(since=0)

    threads = [threading.Thread(target=f, args=(w,), daemon=True)
               for w, f in enumerate((scalar_worker, scalar_worker,
                                      batch_worker, batch_worker,
                                      reader_worker))]
    for t in threads:
        t.start()
    time.sleep(1.0)
    stop.set()
    for t in threads:
        t.join(timeout=20)
    assert not any(t.is_alive() for t in threads), \
        "mixed scalar/batched prov traffic deadlocked"
    assert not errs, errs[:3]
    assert v.read_provenance(), "no records under mixed traffic"
    mf.close()


# --- exhaustive matrix (slow) ------------------------------------------------------


@pytest.mark.slow
@pytest.mark.parametrize("kind", ["bento", "ext4like", "fuse"])
def test_upgrade_torture_exhaustive_matrix(kind):
    """More submitters, repeated swap cycles, every mount kind."""
    # max_rounds keeps total files under the mkfs inode budget (4096)
    _torture(kind, n_submitters=4 if kind == "fuse" else 8,
             swap_cycles=3, phase_s=0.3, n_blocks=32768, max_rounds=450)
