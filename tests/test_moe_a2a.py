"""The shard_map expert-parallel MoE (§Perf cell B) vs the dense oracle."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import registry
from repro.distributed.sharding import ShardingCtx
from repro.launch.mesh import make_host_mesh
from repro.models import moe as M, params as P
from repro.models.moe_a2a import moe_a2a_apply


@pytest.mark.parametrize("arch", ["olmoe-1b-7b", "llama4-scout-17b-a16e"])
def test_a2a_matches_dense(arch):
    cfg = registry.get(arch).smoke
    w = P.materialize(M.moe_specs(cfg), jax.random.PRNGKey(0))
    mesh = make_host_mesh(1, 1)
    ctx = ShardingCtx.for_mesh(mesh)
    x = jax.random.normal(jax.random.PRNGKey(1), (2, 64, cfg.d_model)) * 0.5
    with mesh:
        y1, a1 = M.moe_apply(cfg, ShardingCtx.null(), w, x, impl="dense")
        y2, a2 = moe_a2a_apply(cfg, ctx, w, x)
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), atol=2e-2)
    assert abs(float(a1) - float(a2)) < 1e-3


def test_a2a_gradients_flow():
    cfg = registry.get("olmoe-1b-7b").smoke
    w = P.materialize(M.moe_specs(cfg), jax.random.PRNGKey(0))
    mesh = make_host_mesh(1, 1)
    ctx = ShardingCtx.for_mesh(mesh)
    x = jax.random.normal(jax.random.PRNGKey(1), (1, 32, cfg.d_model)) * 0.5

    def loss(w):
        with mesh:
            y, aux = moe_a2a_apply(cfg, ctx, w, x)
        return jnp.sum(y.astype(jnp.float32) ** 2) + aux

    g = jax.grad(loss)(w)
    gnorm = sum(float(jnp.sum(jnp.abs(l.astype(jnp.float32))))
                for l in jax.tree.leaves(g))
    assert np.isfinite(gnorm) and gnorm > 0  # grads flow through a2a + sort


def test_a2a_capacity_drops_are_bounded():
    """With capacity factor 1.25 and uniform routing, drops are rare; with
    adversarially skewed routing, output degrades gracefully (no NaN)."""
    cfg = registry.get("olmoe-1b-7b").smoke
    w = P.materialize(M.moe_specs(cfg), jax.random.PRNGKey(0))
    # bias the router hard toward expert 0
    w["router"] = w["router"].at[:, 0].add(10.0)
    mesh = make_host_mesh(1, 1)
    ctx = ShardingCtx.for_mesh(mesh)
    x = jax.random.normal(jax.random.PRNGKey(2), (1, 64, cfg.d_model))
    with mesh:
        y, aux = moe_a2a_apply(cfg, ctx, w, x)
    assert bool(jnp.all(jnp.isfinite(y)))
    assert float(aux) > 0  # load-balance loss fires
